//! Microbatch materialization: packed samples → fixed-shape bucket
//! arrays (tokens, segment ids, targets, loss mask) for the static-shape
//! HLO artifacts. This is the runtime half of sequence packing (Krell et
//! al. 2021): samples are concatenated, segment ids isolate attention,
//! and padding carries segment id 0 with a zero loss mask.

use crate::data::corpus::Sample;
use anyhow::{anyhow, Result};

/// A microbatch ready for the artifacts of bucket `seq`.
#[derive(Clone, Debug)]
pub struct PackedMicro {
    pub seq: usize,
    pub tokens: Vec<i32>,
    pub seg: Vec<i32>,
    pub targets: Vec<i32>,
    pub mask: Vec<f32>,
    /// Real (unpadded) token count.
    pub real_tokens: usize,
}

/// Pack `samples` into the smallest bucket from `buckets` that fits.
pub fn pack_micro(samples: &[&Sample], buckets: &[usize]) -> Result<PackedMicro> {
    let total: usize = samples.iter().map(|s| s.len()).sum();
    let seq = buckets
        .iter()
        .copied()
        .find(|&b| b >= total)
        .ok_or(anyhow!("microbatch of {total} tokens exceeds largest bucket {buckets:?}"))?;

    let mut tokens = Vec::with_capacity(seq);
    let mut seg = Vec::with_capacity(seq);
    let mut targets = Vec::with_capacity(seq);
    let mut mask = Vec::with_capacity(seq);
    for (i, s) in samples.iter().enumerate() {
        tokens.extend_from_slice(&s.tokens);
        targets.extend_from_slice(&s.targets);
        seg.extend(std::iter::repeat((i + 1) as i32).take(s.len()));
        mask.extend(std::iter::repeat(1.0f32).take(s.len()));
    }
    let real_tokens = tokens.len();
    tokens.resize(seq, 0);
    targets.resize(seq, 0);
    seg.resize(seq, 0); // padding segment: isolated, masked out
    mask.resize(seq, 0.0);
    Ok(PackedMicro { seq, tokens, seg, targets, mask, real_tokens })
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::data::corpus::Sample;

    fn sample(len: usize, tok: i32) -> Sample {
        Sample { tokens: vec![tok; len], targets: vec![tok + 1; len] }
    }

    #[test]
    fn packs_two_samples_with_segments() {
        let (a, b) = (sample(5, 1), sample(7, 2));
        let p = pack_micro(&[&a, &b], &[16, 32]).unwrap();
        assert_eq!(p.seq, 16);
        assert_eq!(p.real_tokens, 12);
        assert_eq!(&p.seg[..5], &[1; 5]);
        assert_eq!(&p.seg[5..12], &[2; 7]);
        assert_eq!(&p.seg[12..], &[0; 4]);
        assert_eq!(&p.mask[..12], &[1.0; 12]);
        assert_eq!(&p.mask[12..], &[0.0; 4]);
        assert_eq!(p.tokens.len(), 16);
        assert_eq!(p.targets[4], 2);
    }

    #[test]
    fn picks_smallest_fitting_bucket() {
        let a = sample(20, 1);
        let p = pack_micro(&[&a], &[16, 32, 64]).unwrap();
        assert_eq!(p.seq, 32);
    }

    #[test]
    fn errors_when_too_long() {
        let a = sample(100, 1);
        assert!(pack_micro(&[&a], &[16, 32]).is_err());
    }

    #[test]
    fn exact_fit_no_padding() {
        let a = sample(16, 3);
        let p = pack_micro(&[&a], &[16]).unwrap();
        assert_eq!(p.real_tokens, 16);
        assert!(p.mask.iter().all(|&m| m == 1.0));
    }
}
