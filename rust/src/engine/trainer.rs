//! The FSDP trainer: one OS thread per device, PJRT compute, pluggable
//! communication backend. This is the system the paper patches into
//! FSDP, at small scale but with REAL math end to end:
//!
//! ```text
//! per device, per minibatch:
//!   while let Some(micro) = dispatcher.next_micro(dev):   # pull loop
//!     # static dispatch: dev's own plan row, slot order
//!     #   (collective: padded to the common count)
//!     # queue dispatch:  next LPT-ordered microbatch from the shared
//!     #   pool — whichever device frees up first takes it
//!     gather(embed) ─ gather(block l) … ─ block_fwd …   # forward
//!     loss_head → dx
//!     for l = L..1: gather(block l) ─ block_bwd ─ reduce_grad(l, micro.id)
//!     reduce_grad(embed, micro.id)
//!   end_minibatch          # ODC: the ONLY rendezvous
//!   sharded AdamW on owned shards; republish; end_step
//! ```
//!
//! Under `Collective`, every gather/reduce is a barrier (per-layer
//! lockstep); under `Odc` devices free-run to `end_minibatch`, which is
//! what lets LB-Mini give devices different microbatch counts — and
//! what makes runtime placement (`Balancer::Queue`) legal at all: the
//! dispatcher seam ([`crate::balance::dispatch`]) decides WHO runs each
//! packed microbatch, while the id-keyed gradient fold in the one-sided
//! backends keeps every interleaving bit-identical to the single-device
//! oracle (ODC and single-group Hybrid; multi-group Hybrid under Queue
//! is tolerance-equivalent only — see [`crate::comm::HybridComm`]).
//! [`TrainerConfig::device_speed`] emulates a heterogeneous /
//! straggling fleet (a relative-speed sleep multiplier on every
//! microbatch-phase compute call), which queue dispatch absorbs by
//! letting fast devices pull the straggler's share.
//!
//! [`TrainerConfig::fail_at`] / [`TrainerConfig::join_at`] push the
//! same decoupling to its logical end — **ElasticWorld** (see
//! [`crate::comm::membership`]): a device can crash mid-minibatch or
//! join at a minibatch boundary, and the step still completes
//! correctly. Survivors re-pull the dead device's unfinished
//! microbatches (exactly-once, via the elastic dispatch wrapper), the
//! one-sided daemons drop it from the fold quorum, its optimizer shard
//! is adopted by a deterministic ring successor with state recovered
//! from the replicated store, and the `end_minibatch`/`end_step`
//! quorums shrink to the live membership. The id-keyed fold makes the
//! recovered run bit-identical to the healthy one; `Collective`
//! rejects both knobs at validation (a dead rank deadlocks its
//! per-layer barriers — the paradigm contrast the scenario measures).
//! [`TrainRun::recovery_s`] reports the measured recovery overhead,
//! mirrored by the simulator's `RunResult::recovery_s` prediction.
//!
//! Under `Hybrid` (§6.1 two-level sharding) the same free-running loop
//! drives a two-level protocol: gathers are one-sided reads of the
//! device's *node-group replica* (intra-group traffic only) and
//! `reduce_grad` scatter-accumulates within the group, so LB-Mini stays
//! legal; `end_minibatch` completes the group fold and then exchanges
//! global optimizer shards across groups (the only inter-group
//! gradient traffic), and `end_step` republishes optimizer shards and
//! refreshes every group replica between its two barriers. Group size
//! comes from [`TrainerConfig::devices_per_node`] and must tile `world`
//! exactly.
//!
//! ## Zero-copy hot path
//!
//! Each device thread owns a [`BufferPlan`]: a minibatch-scoped
//! [`GatherCache`](crate::comm::GatherCache) (ODC gathers each layer
//! once per MINIBATCH instead of twice per microbatch — §6.2), recycled
//! `Arc` activation/token buffers, and persistent gradient staging.
//! Tensors reach PJRT as shared slices ([`Input::shared_f32`] et al.),
//! so the steady-state loop performs no host-side tensor copies beyond
//! the unavoidable host→device uploads, and no heap allocation. Whether
//! caching is legal is the backend's call
//! ([`CommBackend::gathers_cacheable`]); under `Collective` the cache
//! runs disabled and reproduces the seed gather/barrier sequence
//! exactly.

use crate::balance::cost::CostModel;
use crate::balance::dispatch::{
    make_dispatcher_split, make_elastic_dispatcher_split, Dispatcher, MicroAssignment,
};
use crate::balance::packers::{plan_run_split, PackOpts, Plan};
use crate::balance::split::{ChunkInfo, SplitMap, SplitMode};
use crate::comm::backend::{CommBackend, GatherPolicy, ParamStore};
use crate::comm::membership::Membership;
use crate::comm::{CommStack, FaultPlan, RetryPolicy, TransportKind};
use crate::config::{Balancer, CommScheme, RunSpec, WireDtype};
use crate::data::corpus::{make_dataset, BigramLm, Sample};
use crate::data::distributions::DistSpec;
use crate::engine::bufplan::BufferPlan;
use crate::engine::optimizer::{AdamConfig, AdamState};
use crate::engine::packing::pack_micro;
use crate::runtime::{ComputeService, Input, Manifest};
use crate::util::rng::Rng;
use anyhow::{anyhow, Result};
use std::path::PathBuf;
use std::sync::atomic::{AtomicU64, Ordering};
use std::sync::{Arc, Mutex};
use std::time::{Duration, Instant};

#[derive(Clone, Debug)]
pub struct TrainerConfig {
    /// artifacts/<preset> directory (run `make artifacts` first).
    pub artifacts_dir: PathBuf,
    pub world: usize,
    pub scheme: CommScheme,
    /// Node-group size for `CommScheme::Hybrid` (ignored otherwise).
    /// 0 means "all devices in one group" (a single node — the paper's
    /// hybrid default); any other value must divide `world` exactly.
    pub devices_per_node: usize,
    pub balancer: Balancer,
    /// Samples per minibatch per device.
    pub minibs: usize,
    pub steps: usize,
    pub seed: u64,
    pub adam: AdamConfig,
    /// Route grad-scaling + AdamW through the PJRT chunk kernels instead
    /// of the native Rust loop (validation mode; slower).
    pub pjrt_shard_ops: bool,
    /// Sequence-length distribution (scaled into the bucket range).
    pub len_sigma: f64,
    /// Minibatch-scoped parameter-gather caching (§6.2). Only takes
    /// effect on backends reporting `gathers_cacheable` (ODC); the
    /// equivalence tests toggle it to pin cached == uncached bytes.
    pub gather_cache: bool,
    /// Per-device relative compute speed — the straggler/heterogeneity
    /// scenario. Empty means a homogeneous fleet; otherwise one entry
    /// per device, `1.0` = nominal and `0.25` = a 4×-slower device
    /// (every microbatch-phase PJRT call sleeps `1/speed - 1` times its
    /// own measured duration afterwards). Timing-only: training bytes
    /// are unaffected under every dispatch policy.
    pub device_speed: Vec<f64>,
    /// ElasticWorld fault injection: `(device, step, micro)` — the
    /// device crashes during minibatch `step`, immediately before
    /// running its `micro`-th pulled microbatch of that step (or at the
    /// minibatch's end if it pulls fewer — either way it never reaches
    /// `end_minibatch`, so the membership schedule is exact). Survivors
    /// re-pull its unfinished work, its shard is adopted by the
    /// deterministic ring successor with state recovered from the
    /// replicated store, and barriers shrink to the live set. Requires
    /// a barrier-free scheme — Collective is rejected at validation,
    /// which is the point of the comparison. See `comm::membership`.
    pub fail_at: Vec<(usize, usize, usize)>,
    /// ElasticWorld joins: `(device, step)` — the device sits out steps
    /// `< step` (its share redistributed, its shard served by the ring
    /// successor) and enters at the minibatch boundary, recovering
    /// params + optimizer moments from the replicated store. A join is
    /// bit-identical to a fresh run at the full world size.
    pub join_at: Vec<(usize, usize)>,
    /// ChaosComm fault injection (see [`crate::comm::transport`]): a
    /// deterministic seeded [`FaultPlan`] dropping / duplicating /
    /// reordering / delaying every mailbox message on the one-sided
    /// backends. Transient rates are absorbed by the retransmit ladder
    /// and receiver reassembly — the run stays bit-identical to the
    /// fault-free oracle. `part=src:dst:step` entries permanently
    /// partition a link from `step` on: the src device escalates once
    /// its retry budget is exhausted and crashes out through the
    /// ElasticWorld path (a derived fail-stop at `step` — explicit
    /// `fail_at` cannot be combined with partitions). Noop by default.
    pub fault_plan: FaultPlan,
    /// SeqSplit (`--seq-split`): split any sequence whose predicted cost
    /// exceeds this fraction of the balanced per-device compute budget
    /// into context-parallel chunks, packed and dispatched as singleton
    /// microbatches; the one-sided backends rendezvous each sequence's
    /// chunk gradients at the minibatch flush (see
    /// [`CommBackend::reduce_grad_seq`] and `docs/seqsplit.md`). `0.0`
    /// disables splitting — bit-identical to the pre-SeqSplit trainer.
    /// Requires a barrier-free scheme (ODC/Hybrid) and an LB-Mini or
    /// Queue balancer; a scheduled crash on a chunk-hosting device is
    /// rejected after planning (it would strand the rendezvous).
    pub seq_split: f64,
    /// Chunk-boundary rule for split sequences: `Ring` = equal tokens,
    /// `Zigzag` = equal predicted cost (the causal-attention-aware cut).
    pub seq_split_mode: SplitMode,
    /// FastFold wire precision for gradient pushes on the one-sided
    /// backends: `F32` (default) is bit-exact — every equivalence suite
    /// holds bit-for-bit — while `Bf16` halves pushed gradient bytes via
    /// round-to-nearest-even truncation with per-shard error feedback
    /// (tolerance-equivalent; see `docs/wire_precision.md`). Rejected
    /// under `Collective`, whose in-place rendezvous fold has no
    /// encode/decode stage.
    pub wire_dtype: WireDtype,
    /// WireComm byte transport under the one-sided backends'
    /// mailboxes: `Inproc` (default) is the typed mpsc path, `Shm`
    /// moves framed bytes through lock-free shared-memory rings, `Uds`
    /// through kernel sockets (Unix-domain, TCP-loopback fallback).
    /// Ticket-sequenced delivery keeps all three bit-identical even
    /// under Queue dispatch (`tests/transport_matrix.rs` pins it).
    /// Rejected under `Collective`, which never touches the mailbox
    /// transport. See `docs/transport.md`.
    pub transport: TransportKind,
    /// AsyncPS (`--staleness`): `Some(k)` replaces the synchronous ODC
    /// backend with the bounded-staleness parameter-server tier — one
    /// shard-server thread per shard runs the optimizer the moment its
    /// minibatch quorum lands, while workers free-run into the next
    /// minibatch, admission-gated so the parameters they gather for
    /// minibatch `t` reflect at least the step `t - k` apply.
    /// `Some(0)` still runs the async machinery and is bit-identical to
    /// `None` (pinned by `tests/async_prop.rs`); `k > 0` is
    /// schedule-dependent by design. Requires `--scheme odc`, an
    /// LB-Mini or Queue balancer, a static membership and clean links
    /// (see `docs/asyncps.md` and [`RunSpec::validate`]).
    pub staleness: Option<usize>,
    /// Test/ablation hook: run these exact plans instead of planning.
    /// Microbatch *composition* is semantically meaningful (packing
    /// offsets select positional embeddings), so equivalence tests pin
    /// the plan and vary only the communication scheme / world mapping.
    pub plan_override: Option<Vec<Plan>>,
    /// Paired with `plan_override` when the pinned plans were packed
    /// under SeqSplit: the [`SplitMap`] their chunk virtual ids resolve
    /// through. `None` means the overridden plans contain whole samples
    /// only.
    pub split_override: Option<SplitMap>,
}

impl TrainerConfig {
    pub fn new(artifacts_dir: impl Into<PathBuf>) -> Self {
        TrainerConfig {
            artifacts_dir: artifacts_dir.into(),
            world: 2,
            scheme: CommScheme::Odc,
            devices_per_node: 0,
            balancer: Balancer::LbMini,
            minibs: 4,
            steps: 4,
            seed: 0,
            adam: AdamConfig::default(),
            pjrt_shard_ops: false,
            len_sigma: 0.8,
            gather_cache: true,
            device_speed: Vec::new(),
            fail_at: Vec::new(),
            join_at: Vec::new(),
            fault_plan: FaultPlan::default(),
            seq_split: 0.0,
            seq_split_mode: SplitMode::Zigzag,
            wire_dtype: WireDtype::F32,
            transport: TransportKind::Inproc,
            staleness: None,
            plan_override: None,
            split_override: None,
        }
    }

    /// Project this config onto the shared [`RunSpec`] shape — the
    /// legality matrix both the trainer and the simulator validate
    /// through (`RunSpec::validate` / `validate_engine`).
    pub fn runspec(&self) -> RunSpec {
        RunSpec {
            scheme: self.scheme,
            balancer: self.balancer,
            world: self.world,
            steps: self.steps,
            devices_per_node: self.devices_per_node,
            device_speed: self.device_speed.clone(),
            fail_at: self.fail_at.clone(),
            join_at: self.join_at.clone(),
            fault_plan: self.fault_plan.clone(),
            seq_split: self.seq_split,
            wire_dtype: self.wire_dtype,
            transport: self.transport,
            staleness: self.staleness,
        }
    }

    /// Resolved hybrid group size: `devices_per_node` with 0 meaning the
    /// whole world (a single node).
    pub fn hybrid_group_size(&self) -> usize {
        if self.devices_per_node == 0 {
            self.world
        } else {
            self.devices_per_node
        }
    }
}

#[derive(Clone, Debug)]
pub struct StepLog {
    pub step: usize,
    /// Mean per-token cross-entropy (nats).
    pub loss: f64,
    pub tokens: u64,
    pub wall_s: f64,
}

#[derive(Debug)]
pub struct TrainRun {
    pub logs: Vec<StepLog>,
    /// Final logical parameters per layer (0 = embed) — for equivalence
    /// tests and checkpoint-style inspection.
    pub final_params: Vec<Vec<f32>>,
    pub scheme: CommScheme,
    /// Total device-seconds spent on ElasticWorld recovery work:
    /// orphan-daemon flushes + adopted-shard state recovery and
    /// optimizer updates (rendezvous successors), and join
    /// synchronization + state refresh (late joiners). 0.0 for a
    /// static membership. The sim's `RunResult::recovery_s` predicts
    /// this (fig12-style predicted-vs-measured reporting).
    pub recovery_s: f64,
    /// ChaosComm transport counters (zero on a reliable transport):
    /// retransmissions the retry ladder performed.
    pub retries: u64,
    /// Payload bytes carried by those retransmissions.
    pub retransmitted_bytes: u64,
    /// Links escalated to ElasticWorld after an exhausted retry budget.
    pub escalations: u64,
    /// FastFold: encoded gradient bytes pushed over the wire (0 under
    /// Collective, which folds in place with no explicit wire stage).
    /// Under `WireDtype::Bf16` this is half the f32 figure for the same
    /// run — the quantity the hot-path benches gate.
    pub wire_bytes: u64,
    /// FastFold: seconds spent inside daemon-side fold kernels, summed
    /// across daemon threads (can exceed wall time).
    pub fold_s: f64,
    /// AsyncPS: worst observed admission staleness across all
    /// (worker, minibatch) admissions — how many optimizer applies the
    /// gathered parameters were behind at minibatch start. Bounded by
    /// the configured `k`; 0 on a synchronous run (and on every
    /// `staleness = Some(0)` run, which is the degenerate case).
    pub staleness_max: u64,
    /// AsyncPS: p99 of the same observations (0 when synchronous).
    /// Mirrored by the simulator's `RunResult::staleness_p99`.
    pub staleness_p99: u64,
}

/// The plans `train` would generate for this config (same seeding path).
/// Used by equivalence tests to pin microbatch composition across runs.
pub fn plan_preview(cfg: &TrainerConfig) -> Result<Vec<Plan>> {
    Ok(plan_preview_split(cfg)?.0)
}

/// [`plan_preview`] plus the [`SplitMap`] the plans were packed under
/// (empty when `seq_split` is 0.0). Equivalence tests pin BOTH across
/// runs: chunk virtual ids in a pinned plan are meaningless without the
/// map that generated them.
pub fn plan_preview_split(cfg: &TrainerConfig) -> Result<(Vec<Plan>, SplitMap)> {
    let man = Manifest::load(&cfg.artifacts_dir)?;
    let max_bucket = *man.seq_buckets.iter().max().unwrap();
    let mut rng = Rng::new(cfg.seed);
    let spec =
        DistSpec { median: max_bucket as f64 / 6.0, sigma: cfg.len_sigma, min_len: 4, max_len: max_bucket };
    let n = cfg.steps * cfg.world * cfg.minibs;
    let lens: Vec<usize> = (0..n).map(|_| spec.sample(&mut rng)).collect();
    let cost = CostModel::from_dims(man.n_layers, man.d_model, man.total_params as f64);
    let _ = rng.fork(7); // keep rng stream aligned with train()
    let mut plan_rng = rng.fork(13);
    Ok(plan_run_split(
        cfg.balancer,
        &lens,
        cfg.world,
        cfg.minibs,
        max_bucket,
        &cost,
        &mut plan_rng,
        PackOpts::default(),
        cfg.seq_split,
        cfg.seq_split_mode,
    ))
}

/// Train per the config; returns the loss curve and final parameters.
pub fn train(cfg: &TrainerConfig) -> Result<TrainRun> {
    // Config validation first (none of it needs artifacts on disk). The
    // whole cross-knob legality matrix lives in [`RunSpec::validate`],
    // shared verbatim with the simulator; `validate_engine` adds the
    // engine-only bf16-codec constraint. The returned membership already
    // carries the derived fail-stops of fault-plan partitions.
    let spec = cfg.runspec();
    let membership = spec.validate_engine().map_err(|e| anyhow!("{e}"))?;
    if cfg.pjrt_shard_ops && cfg.staleness.is_some() {
        // Engine-only: the AsyncPS optimizer runs on shard-server
        // threads driving the native AdamW loop; the PJRT chunk-kernel
        // path is a worker-thread validation mode with no client to
        // hand those threads.
        return Err(anyhow!(
            "pjrt_shard_ops requires the synchronous optimizer phase: AsyncPS shard servers \
             run the native AdamW loop, not the PJRT chunk kernels"
        ));
    }
    let fails = spec.derived_fails();
    let man = Manifest::load(&cfg.artifacts_dir)?;
    let host = ComputeService::start(&man)?;

    // --- parameters ------------------------------------------------------
    let layer_lens = man.layer_lens();
    let params = Arc::new(ParamStore::new(&layer_lens, cfg.world));
    for (l, p) in params.layers.iter().enumerate() {
        p.init_from(&man.load_init(l)?);
    }
    // One door for every backend: the CommStack builder routes the
    // scheme (Odc + staleness selects AsyncPs) and re-checks stack
    // legality before any daemon spawns. Chaos layer (when the plan is
    // live) wraps whichever byte-moving base `cfg.transport` selects —
    // the stacks compose (see comm/transport.rs "Byte-moving siblings").
    // NB: built after init_from above — HybridComm seeds its group
    // replicas from the global store.
    let mut stack = CommStack::builder(Arc::clone(&params), cfg.world)
        .membership(Arc::clone(&membership))
        .wire(cfg.wire_dtype)
        .transport(cfg.transport);
    if !cfg.fault_plan.is_noop() {
        stack = stack.faults(cfg.fault_plan.clone(), RetryPolicy::default());
    }
    if let Some(k) = cfg.staleness {
        stack = stack.staleness(k);
    }
    if cfg.scheme == CommScheme::Hybrid {
        stack = stack.groups(cfg.hybrid_group_size());
    }
    let backend: Arc<dyn CommBackend> = stack
        .build(cfg.scheme)
        .map_err(|e| anyhow!("transport {} failed to bind: {e}", cfg.transport))?;

    // --- data + plan -------------------------------------------------------
    let max_bucket = *man.seq_buckets.iter().max().unwrap();
    let mut rng = Rng::new(cfg.seed);
    let spec = DistSpec {
        median: max_bucket as f64 / 6.0,
        sigma: cfg.len_sigma,
        min_len: 4,
        max_len: max_bucket,
    };
    let n = cfg.steps * cfg.world * cfg.minibs;
    let lens: Vec<usize> = (0..n).map(|_| spec.sample(&mut rng)).collect();
    let lm = BigramLm::new(man.vocab, 4, cfg.seed);
    let mut data_rng = rng.fork(7);
    let mut dataset = make_dataset(&lm, &lens, &mut data_rng);

    let cost = CostModel::from_dims(man.n_layers, man.d_model, man.total_params as f64);
    let mut plan_rng = rng.fork(13);
    let (planned, split) = match &cfg.plan_override {
        Some(p) => (
            p.clone(),
            cfg.split_override.clone().unwrap_or_else(|| SplitMap::empty(lens.len())),
        ),
        None => plan_run_split(
            cfg.balancer,
            &lens,
            cfg.world,
            cfg.minibs,
            max_bucket,
            &cost,
            &mut plan_rng,
            PackOpts::default(),
            cfg.seq_split,
            cfg.seq_split_mode,
        ),
    };
    let plans: Arc<Vec<Plan>> = Arc::new(planned);
    if plans.len() != cfg.steps {
        return Err(anyhow!("planned {} steps, expected {}", plans.len(), cfg.steps));
    }
    if plans.iter().any(|p| p.devices() != cfg.world) {
        return Err(anyhow!("plan device count does not match world size"));
    }
    if !split.is_empty() {
        // A scheduled crash (explicit fail_at or a partition's derived
        // fail-stop) on a device that could run a chunk micro would
        // strand the sequence's rendezvous partners in the per-sequence
        // fold — rejected here, after planning, when placement is known.
        // Queue dispatch decides placement at runtime, so ANY scheduled
        // crash could land on a chunk.
        for &(d, step) in &fails {
            let hosts = match cfg.balancer {
                Balancer::Queue => true,
                _ => plans
                    .get(step)
                    .is_some_and(|p| p.micro[d].iter().flatten().any(|&i| split.is_chunk(i))),
            };
            if hosts {
                return Err(anyhow!(
                    "fail_at device {d} can host a split chunk at step {step}: the crash would \
                     strand its sequence's rendezvous partners — disable seq_split or move the \
                     failure to a step without chunks on that device"
                ));
            }
        }
    }
    // SeqSplit: materialize each chunk as a virtual sample slicing its
    // parent's tokens/targets — dataset index == chunk virtual id, and
    // the token totals are conserved (Σ chunk lens == parent len), so
    // the 1/ntok gradient normalization matches the unsplit corpus.
    for c in split.iter() {
        let tokens = dataset[c.parent].tokens[c.start..c.start + c.len].to_vec();
        let targets = dataset[c.parent].targets[c.start..c.start + c.len].to_vec();
        dataset.push(Sample { tokens, targets });
    }
    let samples: Arc<Vec<Sample>> = Arc::new(dataset);
    let split = Arc::new(split);

    // --- dispatch layer ----------------------------------------------------
    // One dispatcher per minibatch, shared by all device threads: static
    // plan replay, or the work-stealing queue under Balancer::Queue. An
    // elastic membership wraps each minibatch's dispatcher so a crashed
    // device's unfinished assignments are orphaned to survivors and an
    // absent device's share is redistributed (exactly-once either way).
    let dispatchers: Arc<Vec<Arc<dyn Dispatcher>>> = Arc::new(
        plans
            .iter()
            .enumerate()
            .map(|(step, p)| {
                if membership.is_static() {
                    make_dispatcher_split(cfg.balancer, cfg.scheme, p, &lens, &cost, &split)
                } else {
                    let crasher: Vec<bool> =
                        (0..cfg.world).map(|d| membership.fails_during(d, step)).collect();
                    let absent: Vec<bool> =
                        (0..cfg.world).map(|d| membership.absent(d, step)).collect();
                    make_elastic_dispatcher_split(
                        cfg.balancer,
                        cfg.scheme,
                        p,
                        &lens,
                        &cost,
                        &crasher,
                        &absent,
                        &split,
                    )
                }
            })
            .collect(),
    );

    // --- shared step metrics ----------------------------------------------
    let tok_count: Arc<Vec<AtomicU64>> = Arc::new((0..cfg.steps).map(|_| AtomicU64::new(0)).collect());
    let loss_sum: Arc<Vec<Mutex<f64>>> = Arc::new((0..cfg.steps).map(|_| Mutex::new(0.0)).collect());
    let wall: Arc<Vec<Mutex<f64>>> = Arc::new((0..cfg.steps).map(|_| Mutex::new(0.0)).collect());
    let recovery: Arc<Mutex<f64>> = Arc::new(Mutex::new(0.0));
    let stale_obs: Arc<Mutex<Vec<u64>>> = Arc::new(Mutex::new(Vec::new()));

    // --- device threads ----------------------------------------------------
    // AsyncPS additionally runs one shard-server thread per shard: the
    // optimizer role moves off the worker threads entirely, applying
    // each minibatch's folded gradient the moment its quorum lands and
    // publishing the shard's apply count on the ParamStore clock that
    // admission-gates the free-running workers.
    std::thread::scope(|s| -> Result<()> {
        let mut handles = Vec::new();
        for dev in 0..cfg.world {
            let slow_extra = match cfg.device_speed.get(dev) {
                Some(&s) => (1.0 / s - 1.0).max(0.0),
                None => 0.0,
            };
            let ctx = DeviceCtx {
                dev,
                cfg: cfg.clone(),
                man: man.clone(),
                svc: host.handle(),
                backend: Arc::clone(&backend),
                params: Arc::clone(&params),
                membership: Arc::clone(&membership),
                dispatchers: Arc::clone(&dispatchers),
                samples: Arc::clone(&samples),
                split: Arc::clone(&split),
                tok_count: Arc::clone(&tok_count),
                loss_sum: Arc::clone(&loss_sum),
                wall: Arc::clone(&wall),
                recovery: Arc::clone(&recovery),
                stale_obs: Arc::clone(&stale_obs),
                slow_extra,
            };
            handles.push(s.spawn(move || device_main(ctx)));
        }
        if cfg.staleness.is_some() {
            for shard in 0..cfg.world {
                let ctx = ServerCtx {
                    shard,
                    cfg: cfg.clone(),
                    backend: Arc::clone(&backend),
                    params: Arc::clone(&params),
                    tok_count: Arc::clone(&tok_count),
                };
                handles.push(s.spawn(move || shard_server_main(ctx)));
            }
        }
        for h in handles {
            h.join().map_err(|_| anyhow!("device thread panicked"))??;
        }
        Ok(())
    })?;

    // --- collect -----------------------------------------------------------
    let logs = (0..cfg.steps)
        .map(|step| {
            let tokens = tok_count[step].load(Ordering::Relaxed);
            StepLog {
                step,
                loss: *loss_sum[step].lock().unwrap() / tokens.max(1) as f64,
                tokens,
                wall_s: *wall[step].lock().unwrap(),
            }
        })
        .collect();
    let final_params = params
        .layers
        .iter()
        .map(|p| {
            let mut out = vec![0.0f32; p.logical_len];
            p.read_logical(&mut out);
            out
        })
        .collect();
    let recovery_s = *recovery.lock().unwrap();
    let fs = backend.fault_stats();
    let hp = backend.hotpath_stats();
    // AsyncPS staleness accounting: one observation per (worker,
    // minibatch) admission; empty on synchronous runs.
    let (staleness_max, staleness_p99) = {
        let mut obs = stale_obs.lock().unwrap().clone();
        if obs.is_empty() {
            (0, 0)
        } else {
            obs.sort_unstable();
            let idx = ((obs.len() as f64 * 0.99).ceil() as usize).saturating_sub(1);
            (*obs.last().unwrap(), obs[idx])
        }
    };
    Ok(TrainRun {
        logs,
        final_params,
        scheme: cfg.scheme,
        recovery_s,
        retries: fs.retries,
        retransmitted_bytes: fs.retransmitted_bytes,
        escalations: fs.escalations,
        wire_bytes: hp.wire_bytes,
        fold_s: hp.fold_ns as f64 * 1e-9,
        staleness_max,
        staleness_p99,
    })
}

/// FastFold streamed gathers: a per-device prefetch worker driven by a
/// posted-request/await pair. While the device computes block `l`, the
/// worker gathers layer `l+1`'s parameters through the backend and the
/// result is adopted into the minibatch-scoped [`GatherCache`] — so the
/// first forward pass of each minibatch overlaps its gathers with
/// compute instead of serializing them.
///
/// Legality is exactly the cache's: params are phase-immutable, so a
/// prefetched gather is bit-identical to a synchronous one (see the
/// phase timeline in [`crate::comm::shared`]). The stream is created
/// only when the backend's [`GatherPolicy`] is cacheable, posts only
/// layers the cache would adopt ([`GatherCache::wants_prefetch`]), and
/// keeps at most ONE request in flight, always awaited within the same
/// microbatch — no prefetch ever spans `end_minibatch`/`end_step`, so
/// the worker is provably idle at every barrier.
struct GatherStream {
    /// `None` after shutdown; dropping the sender stops the worker.
    req_tx: Option<std::sync::mpsc::Sender<usize>>,
    res_rx: std::sync::mpsc::Receiver<(usize, Arc<[f32]>)>,
    /// The one posted-but-not-awaited layer, if any.
    pending: Option<usize>,
    handle: Option<std::thread::JoinHandle<()>>,
}

impl GatherStream {
    fn start(backend: Arc<dyn CommBackend>, dev: usize, padded_lens: Vec<usize>) -> Self {
        let (req_tx, req_rx) = std::sync::mpsc::channel::<usize>();
        let (res_tx, res_rx) = std::sync::mpsc::channel();
        let handle = std::thread::spawn(move || {
            while let Ok(layer) = req_rx.recv() {
                let mut buf = vec![0.0f32; padded_lens[layer]];
                backend.gather_params(dev, layer, &mut buf);
                if res_tx.send((layer, Arc::from(buf))).is_err() {
                    break;
                }
            }
        });
        GatherStream { req_tx: Some(req_tx), res_rx, pending: None, handle: Some(handle) }
    }

    /// Post a prefetch of `layer` unless one is already in flight or the
    /// cache would discard the result (slot already valid this
    /// minibatch — i.e. every microbatch after the first).
    fn post(&mut self, layer: usize, cache: &crate::comm::GatherCache) {
        if self.pending.is_some() || !cache.wants_prefetch(layer) {
            return;
        }
        if let Some(tx) = &self.req_tx {
            if tx.send(layer).is_ok() {
                self.pending = Some(layer);
            }
        }
    }

    /// Await the in-flight prefetch (if any) and deposit it in the
    /// cache. Must run before the posted layer's synchronous gather so
    /// the work is not done twice.
    fn await_into(&mut self, cache: &mut crate::comm::GatherCache) {
        if let Some(layer) = self.pending.take() {
            let (got, buf) = self.res_rx.recv().expect("gather prefetch worker died");
            debug_assert_eq!(got, layer, "prefetch results must arrive in post order");
            cache.adopt_prefetch(got, buf);
        }
    }
}

impl Drop for GatherStream {
    fn drop(&mut self) {
        self.req_tx.take(); // closes the channel; the worker loop exits
        if self.pending.take().is_some() {
            let _ = self.res_rx.recv(); // drain the in-flight result
        }
        if let Some(h) = self.handle.take() {
            let _ = h.join();
        }
    }
}

struct DeviceCtx {
    dev: usize,
    cfg: TrainerConfig,
    man: Manifest,
    svc: ComputeService,
    backend: Arc<dyn CommBackend>,
    params: Arc<ParamStore>,
    /// The elastic membership schedule (all-live when `fail_at`/`join_at`
    /// are empty): drives shard ownership, barriers, and fold quorums.
    membership: Arc<Membership>,
    /// One per minibatch, shared by every device thread.
    dispatchers: Arc<Vec<Arc<dyn Dispatcher>>>,
    samples: Arc<Vec<Sample>>,
    /// SeqSplit chunk map (empty when splitting is off): resolves chunk
    /// virtual ids in dispatched micros to their parent sequence, so
    /// `run_microbatch` routes their pushes through the per-sequence
    /// rendezvous instead of the plain micro fold.
    split: Arc<SplitMap>,
    tok_count: Arc<Vec<AtomicU64>>,
    loss_sum: Arc<Vec<Mutex<f64>>>,
    wall: Arc<Vec<Mutex<f64>>>,
    /// Summed recovery device-seconds (see `TrainRun::recovery_s`).
    recovery: Arc<Mutex<f64>>,
    /// AsyncPS: observed admission staleness, one entry per (worker,
    /// minibatch) admission (see `TrainRun::staleness_max`). Untouched
    /// on synchronous runs.
    stale_obs: Arc<Mutex<Vec<u64>>>,
    /// Straggler emulation: extra sleep per compute call, as a multiple
    /// of the call's own duration (`1/speed - 1`; 0 = nominal device).
    slow_extra: f64,
}

impl DeviceCtx {
    /// The microbatch-phase compute wrapper: every forward/backward PJRT
    /// call routes through here so [`TrainerConfig::device_speed`] can
    /// emulate a slow or heterogeneous device by sleeping a multiple of
    /// the call's own measured duration. Sleeps perturb timing only —
    /// the id-keyed gradient fold keeps the training bytes identical.
    fn compute(&self, name: &str, inputs: Vec<Input>) -> Result<Vec<Vec<f32>>> {
        if self.slow_extra <= 0.0 {
            return self.svc.call(name, inputs);
        }
        let t0 = Instant::now();
        let out = self.svc.call(name, inputs)?;
        let pad = t0.elapsed().mul_f64(self.slow_extra);
        if pad > Duration::ZERO {
            std::thread::sleep(pad);
        }
        Ok(out)
    }
}

/// Owner-side optimizer state of one shard: master parameter copy plus
/// Adam moments. Normally each device holds exactly one (its own
/// shard); under elastic membership a rendezvous successor additionally
/// holds one per adopted shard, recovered from the replicated store.
struct ShardSlot {
    params: Vec<Vec<f32>>,
    adam: Vec<AdamState>,
}

/// Build (or recover) the owner-side state of `shard` as of `step`'s
/// optimizer phase: parameters from the store, Adam moments from the
/// replicated [`crate::comm::OptReplica`] windows (zeroed at
/// construction — exactly `AdamState::new` at step 0), step counter =
/// completed steps. Bit-exact: the previous owner published precisely
/// these bytes at the end of step `step - 1`.
fn recover_slot(params: &ParamStore, shard: usize, step: usize) -> ShardSlot {
    let mut slot = ShardSlot { params: Vec::new(), adam: Vec::new() };
    for (l, p) in params.layers.iter().enumerate() {
        let r = p.shard_range(shard);
        let mut v = vec![0.0f32; r.len()];
        p.buf.read(r.start, &mut v);
        slot.params.push(v);
        let mut st = AdamState::new(r.len());
        params.opt[l].recover(r.start, &mut st.m, &mut st.v);
        st.t = step as u32;
        slot.adam.push(st);
    }
    slot
}

fn device_main(ctx: DeviceCtx) -> Result<()> {
    let man = &ctx.man;
    let dev = ctx.dev;
    let n_layers = man.n_layers;
    let steps = ctx.dispatchers.len();

    // All recurring buffers live in the plan; caching honours the
    // backend's per-level policy (ODC one-sided and Hybrid intra-group
    // gathers cache per minibatch; Collective gathers are rendezvous and
    // must run on every seed call site).
    let policy = if ctx.cfg.gather_cache {
        ctx.backend.gather_policy()
    } else {
        GatherPolicy::Rendezvous
    };
    let mut bufs = BufferPlan::new(&ctx.params, dev, policy);

    // FastFold streamed gathers: one prefetch worker per device, created
    // only when the gather policy is cacheable — the same structural
    // condition that makes reusing (and therefore pre-taking) a gather
    // legal. Collective runs without a stream and keeps the seed call
    // sequence exactly.
    let mut stream = if policy.cacheable() {
        let lens: Vec<usize> = ctx.params.layers.iter().map(|l| l.padded_len()).collect();
        Some(GatherStream::start(Arc::clone(&ctx.backend), dev, lens))
    } else {
        None
    };

    // Late joiner: sit out the early steps (the membership schedule
    // already routed our share to survivors), then enter exactly at the
    // join boundary, once the previous step's parameters and replicated
    // optimizer state are fully republished.
    let join = ctx.membership.joins_at(dev);
    if join > 0 {
        // The sit-out wait is NOT recovery work (it scales with the
        // join step, not with recovery) — only the state refresh after
        // entry is, and the optimizer loop below times it.
        ctx.backend.await_join(dev);
    }

    // Owner-side optimizer state per shard, recovered lazily the first
    // step this device serves the shard. Static membership: exactly one
    // slot (our own), built at step 0 from the freshly initialized
    // store and the zeroed moment replicas — the seed behaviour, bit
    // for bit.
    let mut slots: Vec<Option<ShardSlot>> = (0..ctx.cfg.world).map(|_| None).collect();

    // Chunk staging for the PJRT validation path (reused across all
    // layers and steps; empty and never touched when the native Rust
    // AdamW loop runs).
    let mut adam_stage: Vec<Arc<[f32]>> = if ctx.cfg.pjrt_shard_ops {
        (0..5).map(|i| vec![0.0f32; if i < 4 { man.chunk } else { 7 }].into()).collect()
    } else {
        Vec::new()
    };

    // ElasticWorld fault injection: the (step, pull index) this worker
    // crashes at, if any.
    let my_fail: Option<(usize, usize)> =
        ctx.cfg.fail_at.iter().find(|f| f.0 == dev).map(|f| (f.1, f.2));

    for step in join..steps {
        // AsyncPS admission gate (SSP): before touching minibatch
        // `step`, wait until every shard's apply count covers step
        // `step - k` — the parameters gathered below are then at most
        // `k` applies behind. `k = 0` makes this exactly the barrier
        // the synchronous scheme has (no apply/gather overlap at all),
        // which is what the bit-identity suite pins.
        if let Some(k) = ctx.cfg.staleness {
            let target = (step as u64).saturating_sub(k as u64);
            let min_applied = ctx.params.wait_min_applies(target);
            let observed = (step as u64).saturating_sub(min_applied);
            ctx.stale_obs.lock().unwrap().push(observed);
        }
        let t0 = Instant::now();
        // The dispatch pull loop: static dispatch serves this device its
        // own plan row (Collective: padded to the common count so the
        // barrier schedule stays in lockstep); queue dispatch serves the
        // next LPT-ordered microbatch from the shared pool to whichever
        // free-running device asks first.
        let disp = ctx.dispatchers[step].as_ref();
        let mut pulls = 0usize;
        let mut crashed = false;
        while let Some(a) = disp.next_micro(dev) {
            if my_fail == Some((step, pulls)) {
                // Simulated crash: the pulled-but-unrun assignment is
                // orphaned for survivors; this worker vanishes without
                // reaching the fold quorum or another barrier. Its
                // daemon lives on as a shard server until the
                // rendezvous successor adopts it (comm::membership).
                disp.report_failed(dev);
                crashed = true;
                break;
            }
            pulls += 1;
            if a.samples.is_empty() {
                idle_participation(&ctx, n_layers, &mut bufs)?;
                continue;
            }
            run_microbatch(&ctx, &mut bufs, step, &a, stream.as_mut())?;
            if ctx.backend.link_escalated(dev) {
                // ChaosComm escalation: a link's retry budget is gone
                // for good. The backend already retracted this
                // microbatch's landed pieces (all-or-nothing), so
                // reporting the failure orphans it to a survivor for an
                // exactly-once re-run; this worker crashes out exactly
                // like a fail_at victim (the membership schedule already
                // carries its derived fail-stop).
                disp.report_failed(dev);
                crashed = true;
                break;
            }
        }
        if !crashed && matches!(my_fail, Some((s, _)) if s == step) {
            // Scheduled to crash this step but the work ran dry first:
            // crash at the minibatch's end instead (still before the
            // fold quorum), keeping the membership schedule exact.
            disp.report_failed(dev);
            crashed = true;
        }
        if crashed {
            return Ok(());
        }

        ctx.backend.end_minibatch(dev);
        if ctx.backend.link_escalated(dev) {
            // Escalated inside the minibatch epilogue (e.g. the Done
            // broadcast hit the partitioned link first): crash out
            // before the optimizer phase — the gradient flush never
            // completed for this device, and the fold quorum already
            // excludes it via its derived fail-stop.
            disp.report_failed(dev);
            return Ok(());
        }

        if ctx.cfg.staleness.is_some() {
            // AsyncPS: the optimizer role lives on the shard-server
            // threads (`shard_server_main`) — the worker's Done above
            // completed its part of the minibatch quorum, and it
            // free-runs into the next minibatch without waiting for
            // the apply. Cached gathers still expire at the minibatch
            // edge: the next admission re-reads whatever parameter
            // versions the bound admits.
            bufs.cache.invalidate();
            if ctx.membership.first_completing(step) == dev {
                *ctx.wall[step].lock().unwrap() = t0.elapsed().as_secs_f64();
            }
            continue;
        }

        // ---- server role: sharded AdamW on every shard this device
        // serves at this step — its own, plus any adopted from a dead
        // (or not-yet-joined) peer via the rendezvous rule ----
        let ntok = ctx.tok_count[step].load(Ordering::SeqCst).max(1) as f32;
        let owned = ctx.membership.shards_owned_by(dev, step);
        let replicate = !ctx.membership.is_static();
        for &shard in &owned {
            // Recovery work = the ownership HANDOFF itself: the step a
            // peer's shard is first adopted (orphan flush + state
            // re-read), or our own first step back after a join (the
            // replica refresh path). Serving an adopted shard on later
            // steps is the new steady state, not recovery — this keeps
            // the measurement one-shot per event, the same quantity the
            // sim's recovery_epilogue_s predicts.
            let recovering =
                (shard != dev && slots[shard].is_none()) || (join > 0 && step == join && shard == dev);
            let t_rec = recovering.then(Instant::now);
            if shard != dev {
                // complete the orphaned shard server's minibatch fold
                ctx.backend.flush_shard(shard);
            }
            if slots[shard].is_none() {
                slots[shard] = Some(recover_slot(&ctx.params, shard, step));
            }
            let slot = slots[shard].as_mut().expect("slot just ensured");
            for l in 0..=n_layers {
                let p = &ctx.params.layers[l];
                let g = &mut bufs.gshard[..p.shard_len];
                ctx.backend.take_grad_shard(shard, l, g);
                if ctx.cfg.pjrt_shard_ops {
                    pjrt_adam_step(&ctx, &mut slot.params[l], g, &mut slot.adam[l], ntok, &mut adam_stage)?;
                } else {
                    for x in g.iter_mut() {
                        *x /= ntok;
                    }
                    slot.adam[l].step(&ctx.cfg.adam, &mut slot.params[l], g);
                }
                let r = p.shard_range(shard);
                p.buf.write(r.start, &slot.params[l]);
                // Classical PS replication: publish the moments so a
                // successor or a returning joiner recovers exact state.
                // Elastic schedules only — under a static membership
                // nothing can ever read them back, so the steady-state
                // optimizer phase stays a single shard write.
                if replicate {
                    ctx.params.opt[l].publish(r.start, &slot.adam[l].m, &slot.adam[l].v);
                }
            }
            // Advance the shard's apply count on the ParamStore clock.
            // Synchronous schemes never wait on it (the end_step barrier
            // already orders everything), but keeping it current means
            // the clock is a truthful version record under every scheme.
            ctx.params.publish_apply(shard);
            if let Some(t) = t_rec {
                *ctx.recovery.lock().unwrap() += t.elapsed().as_secs_f64();
            }
        }
        // Ownership can revert at a join boundary: drop slots no longer
        // served so a stale copy can never be written back.
        for (s, slot) in slots.iter_mut().enumerate() {
            if !owned.contains(&s) {
                *slot = None;
            }
        }
        ctx.backend.end_step(dev);
        // Params republished at the barrier: cached gathers are stale.
        bufs.cache.invalidate();
        if ctx.membership.first_completing(step) == dev {
            *ctx.wall[step].lock().unwrap() = t0.elapsed().as_secs_f64();
        }
    }
    Ok(())
}

/// Everything one AsyncPS shard-server thread needs (a deliberately
/// smaller surface than [`DeviceCtx`]: servers never touch PJRT, the
/// dispatcher, or the loss metrics).
struct ServerCtx {
    shard: usize,
    cfg: TrainerConfig,
    backend: Arc<dyn CommBackend>,
    params: Arc<ParamStore>,
    /// Shared with the workers: the token totals their pushes were
    /// weighted against. Each worker's adds for minibatch `t` are
    /// sequenced before its Done, which is sequenced before the flush
    /// reply that wakes this thread — so the load below is final.
    tok_count: Arc<Vec<AtomicU64>>,
}

/// The AsyncPS optimizer tier: one thread per shard, decoupled from the
/// worker threads. Each iteration blocks in [`CommBackend::server_flush`]
/// until minibatch `step`'s fold quorum lands on this shard's daemon
/// (all `world` Dones received — the same id-keyed fold as the
/// synchronous path, so the folded bytes are dispatch-order-invariant),
/// then runs the identical 1/ntok + AdamW + write-back sequence the
/// synchronous optimizer phase runs, and finally publishes the apply on
/// the ParamStore clock — the event the workers' admission gate waits
/// on. Writes take the shard's write gate so a concurrent worker gather
/// (legal when `k > 0`) sees a torn-free before-or-after image of each
/// layer; with `k = 0` the admission gate means no gather is ever in
/// flight here, reproducing the synchronous schedule exactly.
fn shard_server_main(ctx: ServerCtx) -> Result<()> {
    let shard = ctx.shard;
    let mut slot = recover_slot(&ctx.params, shard, 0);
    let max_shard = ctx.params.layers.iter().map(|p| p.shard_len).max().unwrap_or(0);
    let mut gshard = vec![0.0f32; max_shard];
    for step in 0..ctx.cfg.steps {
        ctx.backend.server_flush(shard, step);
        let ntok = ctx.tok_count[step].load(Ordering::SeqCst).max(1) as f32;
        for (l, p) in ctx.params.layers.iter().enumerate() {
            let g = &mut gshard[..p.shard_len];
            ctx.backend.take_grad_shard(shard, l, g);
            for x in g.iter_mut() {
                *x /= ntok;
            }
            slot.adam[l].step(&ctx.cfg.adam, &mut slot.params[l], g);
            let r = p.shard_range(shard);
            let _gate = ctx.params.shard_write(shard);
            p.buf.write(r.start, &slot.params[l]);
        }
        ctx.params.publish_apply(shard);
    }
    Ok(())
}

/// Forward + backward of one dispatched microbatch through PJRT,
/// zero-copy: gathered layers and saved activations are `Arc` slices
/// shared into every call; the only owned-`Vec` handoff left is `dx`,
/// which moves (not clones) through the backward chain. Every gradient
/// push carries the assignment's global microbatch id — the fold key
/// that makes the result independent of dispatch order.
fn run_microbatch(
    ctx: &DeviceCtx,
    bufs: &mut BufferPlan,
    step: usize,
    a: &MicroAssignment,
    mut stream: Option<&mut GatherStream>,
) -> Result<()> {
    let man = &ctx.man;
    let dev = ctx.dev;
    let n_layers = man.n_layers;
    let backend = ctx.backend.as_ref();
    let micro: &[usize] = &a.samples;
    // SeqSplit: chunk virtual ids only ever appear as singleton micros
    // (the packers keep context-parallel chunks un-packed); their pushes
    // route through the per-sequence rendezvous fold instead of the
    // plain micro fold, keyed (parent, chunk index) so any dispatch
    // interleaving reconstitutes the same sequence gradient.
    debug_assert!(
        micro.len() == 1 || micro.iter().all(|&i| !ctx.split.is_chunk(i)),
        "chunk virtual id packed into a multi-sample micro"
    );
    let chunk: Option<&ChunkInfo> =
        if micro.len() == 1 { ctx.split.get(micro[0]) } else { None };
    let push = |layer: usize, gp: &[f32]| match chunk {
        Some(c) => backend.reduce_grad_seq(
            dev,
            layer,
            gp,
            1.0,
            c.parent as u64,
            c.index as u32,
            c.count as u32,
        ),
        None => backend.reduce_grad(dev, layer, gp, 1.0, a.id),
    };
    let refs: Vec<&Sample> = micro.iter().map(|&i| &ctx.samples[i]).collect();
    let packed = pack_micro(&refs, &man.seq_buckets)?;
    let s = packed.seq;
    ctx.tok_count[step].fetch_add(packed.real_tokens as u64, Ordering::SeqCst);

    // Adopt the packed tensors into recycled shared buffers: after
    // warm-up these are in-place copies, and every PJRT call below
    // shares them by refcount instead of cloning.
    let tokens = bufs.i32_pool.adopt(packed.tokens);
    let seg = bufs.i32_pool.adopt(packed.seg);
    let targets = bufs.i32_pool.adopt(packed.targets);
    let mask = bufs.f32_pool.adopt(packed.mask);

    // ---- forward ----
    // Streamed gathers: post block 1's gather before touching the
    // embedding, then keep exactly one prefetch in flight — layer l+1
    // posted while block l computes, awaited (and adopted into the
    // cache) at the top of the next iteration. Every post is consumed
    // within this microbatch, so no prefetch ever crosses a barrier.
    if n_layers >= 1 {
        if let Some(s) = stream.as_deref_mut() {
            s.post(1, &bufs.cache);
        }
    }
    let emb = bufs.cache.gather(backend, 0);
    let mut out = ctx.compute(
        &format!("embed_fwd_s{s}"),
        vec![Input::shared_f32(&emb, man.embed_params), Input::shared_i32_all(&tokens)],
    )?;
    let mut x = bufs.f32_pool.adopt(out.swap_remove(0));

    debug_assert!(bufs.acts.is_empty(), "activation stack leaked from a previous microbatch");
    for l in 1..=n_layers {
        if let Some(s) = stream.as_deref_mut() {
            s.await_into(&mut bufs.cache);
            if l < n_layers {
                s.post(l + 1, &bufs.cache);
            }
        }
        let flat = bufs.cache.gather(backend, l);
        let mut out = ctx.compute(
            &format!("block_fwd_s{s}"),
            vec![
                Input::shared_f32(&flat, man.block_params),
                Input::shared_f32_all(&x),
                Input::shared_i32_all(&seg),
            ],
        )?;
        let next = bufs.f32_pool.adopt(out.swap_remove(0));
        bufs.acts.push(std::mem::replace(&mut x, next));
    }

    let mut out = ctx.compute(
        &format!("loss_head_s{s}"),
        vec![
            Input::shared_f32(&emb, man.embed_params),
            Input::shared_f32_all(&x),
            Input::shared_i32_all(&targets),
            Input::shared_f32_all(&mask),
        ],
    )?;
    // outputs: [loss_sum, ntok, dx, demb_head]
    let demb_head = out.pop().ok_or_else(|| anyhow!("loss_head: missing demb output"))?;
    let mut dx = out.pop().ok_or_else(|| anyhow!("loss_head: missing dx output"))?;
    let _ntok = out.pop();
    let loss_sum = out.pop().ok_or_else(|| anyhow!("loss_head: missing loss output"))?;
    *ctx.loss_sum[step].lock().unwrap() += loss_sum[0] as f64;
    bufs.f32_pool.recycle(x);

    // ---- backward (recompute per layer from saved inputs) ----
    for l in (1..=n_layers).rev() {
        let flat = bufs.cache.gather(backend, l);
        let act = bufs.acts.pop().expect("activation for block l-1");
        let mut out = ctx.compute(
            &format!("block_bwd_s{s}"),
            vec![
                Input::shared_f32(&flat, man.block_params),
                Input::shared_f32_all(&act),
                Input::shared_i32_all(&seg),
                Input::F32(dx),
            ],
        )?;
        bufs.f32_pool.recycle(act);
        dx = out.swap_remove(0);
        let dflat = out.pop().ok_or_else(|| anyhow!("block_bwd: missing grad output"))?;
        let p = &ctx.params.layers[l];
        let gp = &mut bufs.grad_pad[..p.padded_len()];
        gp[..man.block_params].copy_from_slice(&dflat);
        gp[man.block_params..].fill(0.0);
        push(l, gp);
    }

    // embedding gradient: head (tied weights) + input scatter-add
    let mut out = ctx.compute(
        &format!("embed_bwd_s{s}"),
        vec![Input::shared_i32_all(&tokens), Input::F32(dx)],
    )?;
    let demb_in = out.swap_remove(0);
    if demb_head.len() != man.embed_params || demb_in.len() != man.embed_params {
        return Err(anyhow!(
            "embed grad size mismatch: head {} / input {} vs embed_params {}",
            demb_head.len(),
            demb_in.len(),
            man.embed_params
        ));
    }
    let p = &ctx.params.layers[0];
    let gp = &mut bufs.grad_pad[..p.padded_len()];
    for (slot, (h, i)) in gp[..man.embed_params].iter_mut().zip(demb_head.iter().zip(&demb_in)) {
        *slot = h + i;
    }
    gp[man.embed_params..].fill(0.0);
    push(0, gp);

    // Return the microbatch tensors to their pools (uniquely owned
    // again: the service drops its input clones before replying).
    bufs.i32_pool.recycle(tokens);
    bufs.i32_pool.recycle(seg);
    bufs.i32_pool.recycle(targets);
    bufs.f32_pool.recycle(mask);

    // ChaosComm escalation mid-microbatch: the backend retracted (or
    // never delivered) this microbatch's gradient pieces, and the caller
    // is about to orphan the assignment for an exactly-once re-run on a
    // survivor — so undo the metric contributions counted above, or the
    // re-run would double-count its tokens (and skew the 1/ntok gradient
    // normalization away from the oracle).
    if backend.link_escalated(dev) {
        ctx.tok_count[step].fetch_sub(packed.real_tokens as u64, Ordering::SeqCst);
        *ctx.loss_sum[step].lock().unwrap() -= loss_sum[0] as f64;
    }
    Ok(())
}

/// A padded empty slot under Collective: the device must join exactly the
/// same barrier sequence as a real microbatch — gathers in forward, then
/// gather+reduce per layer in backward, then the embed reduce — with a
/// zero-weight contribution. Under ODC this is a no-op by construction.
/// Gathers route through the (disabled) cache so the call sequence and
/// buffer reuse match `run_microbatch` one-for-one.
fn idle_participation(ctx: &DeviceCtx, n_layers: usize, bufs: &mut BufferPlan) -> Result<()> {
    if matches!(ctx.cfg.scheme, CommScheme::Odc | CommScheme::Hybrid) {
        return Ok(());
    }
    let dev = ctx.dev;
    let backend = ctx.backend.as_ref();
    let _ = bufs.cache.gather(backend, 0);
    for l in 1..=n_layers {
        let _ = bufs.cache.gather(backend, l);
    }
    for l in (1..=n_layers).rev() {
        let _ = bufs.cache.gather(backend, l);
        let p = &ctx.params.layers[l];
        bufs.grad_pad[..p.padded_len()].fill(0.0);
        ctx.backend.reduce_grad(dev, l, &bufs.grad_pad[..p.padded_len()], 0.0, 0);
    }
    let p = &ctx.params.layers[0];
    bufs.grad_pad[..p.padded_len()].fill(0.0);
    ctx.backend.reduce_grad(dev, 0, &bufs.grad_pad[..p.padded_len()], 0.0, 0);
    Ok(())
}

/// Validation path: scale + AdamW through the PJRT chunk kernels
/// (`accum_chunk` is exercised by the scatter-accumulate tests; here we
/// run `adam_chunk` over the shard in fixed-size chunks). `stage` holds
/// five reusable shared buffers owned by `device_main` — four chunk
/// tensors (p, m, v, g) plus the 7-element hyperparameter vector — and
/// is rewritten in place each call: the service drops its clones before
/// replying, so the buffers are uniquely owned again between calls.
fn pjrt_adam_step(
    ctx: &DeviceCtx,
    p: &mut [f32],
    g: &mut [f32],
    st: &mut AdamState,
    ntok: f32,
    stage: &mut [Arc<[f32]>],
) -> Result<()> {
    for x in g.iter_mut() {
        *x /= ntok;
    }
    st.t += 1;
    let (bc1, bc2) = st.bias_corrections(&ctx.cfg.adam);
    let a = &ctx.cfg.adam;
    let (chunks, hp) = stage.split_at_mut(4);
    Arc::get_mut(&mut hp[0])
        .expect("hp buffer uniquely owned between calls")
        .copy_from_slice(&[a.lr, a.beta1, a.beta2, a.eps, a.weight_decay, bc1, bc2]);
    let c = ctx.man.chunk;
    let mut off = 0;
    while off < p.len() {
        let n = c.min(p.len() - off);
        for (buf, src) in chunks.iter_mut().zip([&p[off..off + n], &st.m[off..off + n], &st.v[off..off + n], &g[off..off + n]]) {
            let dst = Arc::get_mut(buf).expect("stage buffer uniquely owned between calls");
            dst[..n].copy_from_slice(src);
            dst[n..].fill(0.0);
        }
        let out = ctx.svc.call(
            "adam_chunk",
            vec![
                Input::shared_f32_all(&chunks[0]),
                Input::shared_f32_all(&chunks[1]),
                Input::shared_f32_all(&chunks[2]),
                Input::shared_f32_all(&chunks[3]),
                Input::shared_f32_all(&hp[0]),
            ],
        )?;
        p[off..off + n].copy_from_slice(&out[0][..n]);
        st.m[off..off + n].copy_from_slice(&out[1][..n]);
        st.v[off..off + n].copy_from_slice(&out[2][..n]);
        off += n;
    }
    Ok(())
}
