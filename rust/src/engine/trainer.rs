//! The FSDP trainer: one OS thread per device, PJRT compute, pluggable
//! communication backend. This is the system the paper patches into
//! FSDP, at small scale but with REAL math end to end:
//!
//! ```text
//! per device, per minibatch:
//!   for each local microbatch (collective: padded to the common count):
//!     gather(embed) ─ gather(block l) … ─ block_fwd …   # forward
//!     loss_head → dx
//!     for l = L..1: gather(block l) ─ block_bwd ─ reduce_grad(l)
//!     reduce_grad(embed)
//!   end_minibatch          # ODC: the ONLY rendezvous
//!   sharded AdamW on owned shards; republish; end_step
//! ```
//!
//! Under `Collective`, every gather/reduce is a barrier (per-layer
//! lockstep); under `Odc` devices free-run to `end_minibatch`, which is
//! what lets LB-Mini give devices different microbatch counts.

use crate::balance::cost::CostModel;
use crate::balance::packers::{plan_run, Plan};
use crate::comm::backend::{CommBackend, ParamStore};
use crate::comm::{CollectiveComm, OdcComm};
use crate::config::{Balancer, CommScheme};
use crate::data::corpus::{make_dataset, BigramLm, Sample};
use crate::data::distributions::DistSpec;
use crate::engine::optimizer::{AdamConfig, AdamState};
use crate::engine::packing::pack_micro;
use crate::runtime::{ComputeService, Input, Manifest};
use crate::util::rng::Rng;
use anyhow::{anyhow, Result};
use std::path::PathBuf;
use std::sync::atomic::{AtomicU64, Ordering};
use std::sync::{Arc, Mutex};
use std::time::Instant;

#[derive(Clone, Debug)]
pub struct TrainerConfig {
    /// artifacts/<preset> directory (run `make artifacts` first).
    pub artifacts_dir: PathBuf,
    pub world: usize,
    pub scheme: CommScheme,
    pub balancer: Balancer,
    /// Samples per minibatch per device.
    pub minibs: usize,
    pub steps: usize,
    pub seed: u64,
    pub adam: AdamConfig,
    /// Route grad-scaling + AdamW through the PJRT chunk kernels instead
    /// of the native Rust loop (validation mode; slower).
    pub pjrt_shard_ops: bool,
    /// Sequence-length distribution (scaled into the bucket range).
    pub len_sigma: f64,
    /// Test/ablation hook: run these exact plans instead of planning.
    /// Microbatch *composition* is semantically meaningful (packing
    /// offsets select positional embeddings), so equivalence tests pin
    /// the plan and vary only the communication scheme / world mapping.
    pub plan_override: Option<Vec<Plan>>,
}

impl TrainerConfig {
    pub fn new(artifacts_dir: impl Into<PathBuf>) -> Self {
        TrainerConfig {
            artifacts_dir: artifacts_dir.into(),
            world: 2,
            scheme: CommScheme::Odc,
            balancer: Balancer::LbMini,
            minibs: 4,
            steps: 4,
            seed: 0,
            adam: AdamConfig::default(),
            pjrt_shard_ops: false,
            len_sigma: 0.8,
            plan_override: None,
        }
    }
}

#[derive(Clone, Debug)]
pub struct StepLog {
    pub step: usize,
    /// Mean per-token cross-entropy (nats).
    pub loss: f64,
    pub tokens: u64,
    pub wall_s: f64,
}

#[derive(Debug)]
pub struct TrainRun {
    pub logs: Vec<StepLog>,
    /// Final logical parameters per layer (0 = embed) — for equivalence
    /// tests and checkpoint-style inspection.
    pub final_params: Vec<Vec<f32>>,
    pub scheme: CommScheme,
}

/// The plans `train` would generate for this config (same seeding path).
/// Used by equivalence tests to pin microbatch composition across runs.
pub fn plan_preview(cfg: &TrainerConfig) -> Result<Vec<Plan>> {
    let man = Manifest::load(&cfg.artifacts_dir)?;
    let max_bucket = *man.seq_buckets.iter().max().unwrap();
    let mut rng = Rng::new(cfg.seed);
    let spec =
        DistSpec { median: max_bucket as f64 / 6.0, sigma: cfg.len_sigma, min_len: 4, max_len: max_bucket };
    let n = cfg.steps * cfg.world * cfg.minibs;
    let lens: Vec<usize> = (0..n).map(|_| spec.sample(&mut rng)).collect();
    let cost = CostModel::from_dims(man.n_layers, man.d_model, man.total_params as f64);
    let _ = rng.fork(7); // keep rng stream aligned with train()
    let mut plan_rng = rng.fork(13);
    Ok(plan_run(cfg.balancer, &lens, cfg.world, cfg.minibs, max_bucket, &cost, &mut plan_rng))
}

/// Train per the config; returns the loss curve and final parameters.
pub fn train(cfg: &TrainerConfig) -> Result<TrainRun> {
    let man = Manifest::load(&cfg.artifacts_dir)?;
    if cfg.scheme == CommScheme::Collective && cfg.balancer == Balancer::LbMini {
        return Err(anyhow!("LB-Mini requires ODC (devices run unequal microbatch counts)"));
    }
    let host = ComputeService::start(&man)?;

    // --- parameters ------------------------------------------------------
    let layer_lens = man.layer_lens();
    let params = Arc::new(ParamStore::new(&layer_lens, cfg.world));
    for (l, p) in params.layers.iter().enumerate() {
        p.init_from(&man.load_init(l)?);
    }
    let backend: Arc<dyn CommBackend> = match cfg.scheme {
        CommScheme::Collective => Arc::new(CollectiveComm::new(Arc::clone(&params), cfg.world)),
        CommScheme::Odc => Arc::new(OdcComm::new(Arc::clone(&params), cfg.world)),
    };

    // --- data + plan -------------------------------------------------------
    let max_bucket = *man.seq_buckets.iter().max().unwrap();
    let mut rng = Rng::new(cfg.seed);
    let spec = DistSpec {
        median: max_bucket as f64 / 6.0,
        sigma: cfg.len_sigma,
        min_len: 4,
        max_len: max_bucket,
    };
    let n = cfg.steps * cfg.world * cfg.minibs;
    let lens: Vec<usize> = (0..n).map(|_| spec.sample(&mut rng)).collect();
    let lm = BigramLm::new(man.vocab, 4, cfg.seed);
    let mut data_rng = rng.fork(7);
    let samples: Arc<Vec<Sample>> = Arc::new(make_dataset(&lm, &lens, &mut data_rng));

    let cost = CostModel::from_dims(man.n_layers, man.d_model, man.total_params as f64);
    let mut plan_rng = rng.fork(13);
    let plans: Arc<Vec<Plan>> = Arc::new(match &cfg.plan_override {
        Some(p) => p.clone(),
        None => plan_run(cfg.balancer, &lens, cfg.world, cfg.minibs, max_bucket, &cost, &mut plan_rng),
    });
    if plans.len() != cfg.steps {
        return Err(anyhow!("planned {} steps, expected {}", plans.len(), cfg.steps));
    }
    if plans.iter().any(|p| p.devices() != cfg.world) {
        return Err(anyhow!("plan device count does not match world size"));
    }

    // --- shared step metrics ----------------------------------------------
    let tok_count: Arc<Vec<AtomicU64>> = Arc::new((0..cfg.steps).map(|_| AtomicU64::new(0)).collect());
    let loss_sum: Arc<Vec<Mutex<f64>>> = Arc::new((0..cfg.steps).map(|_| Mutex::new(0.0)).collect());
    let wall: Arc<Vec<Mutex<f64>>> = Arc::new((0..cfg.steps).map(|_| Mutex::new(0.0)).collect());

    // --- device threads ----------------------------------------------------
    std::thread::scope(|s| -> Result<()> {
        let mut handles = Vec::new();
        for dev in 0..cfg.world {
            let ctx = DeviceCtx {
                dev,
                cfg: cfg.clone(),
                man: man.clone(),
                svc: host.handle(),
                backend: Arc::clone(&backend),
                params: Arc::clone(&params),
                plans: Arc::clone(&plans),
                samples: Arc::clone(&samples),
                tok_count: Arc::clone(&tok_count),
                loss_sum: Arc::clone(&loss_sum),
                wall: Arc::clone(&wall),
            };
            handles.push(s.spawn(move || device_main(ctx)));
        }
        for h in handles {
            h.join().map_err(|_| anyhow!("device thread panicked"))??;
        }
        Ok(())
    })?;

    // --- collect -----------------------------------------------------------
    let logs = (0..cfg.steps)
        .map(|step| {
            let tokens = tok_count[step].load(Ordering::Relaxed);
            StepLog {
                step,
                loss: *loss_sum[step].lock().unwrap() / tokens.max(1) as f64,
                tokens,
                wall_s: *wall[step].lock().unwrap(),
            }
        })
        .collect();
    let final_params = params
        .layers
        .iter()
        .map(|p| {
            let mut out = vec![0.0f32; p.logical_len];
            p.read_logical(&mut out);
            out
        })
        .collect();
    Ok(TrainRun { logs, final_params, scheme: cfg.scheme })
}

struct DeviceCtx {
    dev: usize,
    cfg: TrainerConfig,
    man: Manifest,
    svc: ComputeService,
    backend: Arc<dyn CommBackend>,
    params: Arc<ParamStore>,
    plans: Arc<Vec<Plan>>,
    samples: Arc<Vec<Sample>>,
    tok_count: Arc<Vec<AtomicU64>>,
    loss_sum: Arc<Vec<Mutex<f64>>>,
    wall: Arc<Vec<Mutex<f64>>>,
}

fn device_main(ctx: DeviceCtx) -> Result<()> {
    let man = &ctx.man;
    let dev = ctx.dev;
    let n_layers = man.n_layers;
    let embed_pad = ctx.params.layers[0].padded_len();
    let block_pad = ctx.params.layers[1].padded_len();

    // reusable buffers
    let mut emb_buf = vec![0.0f32; embed_pad];
    let mut flat_buf = vec![0.0f32; block_pad];
    let mut grad_pad = vec![0.0f32; embed_pad.max(block_pad)];

    // local master copy of owned shards + Adam state
    let mut shards: Vec<Vec<f32>> = ctx
        .params
        .layers
        .iter()
        .map(|p| {
            let r = p.shard_range(dev);
            let mut v = vec![0.0f32; r.len()];
            p.buf.read(r.start, &mut v);
            v
        })
        .collect();
    let mut adam: Vec<AdamState> = shards.iter().map(|s| AdamState::new(s.len())).collect();
    let mut gshard = vec![0.0f32; ctx.params.layers.iter().map(|p| p.shard_len).max().unwrap()];

    for (step, plan) in ctx.plans.iter().enumerate() {
        let t0 = Instant::now();
        let my = &plan.micro[dev];
        // Collective needs lockstep over the common (padded) count.
        let m_count = match ctx.cfg.scheme {
            CommScheme::Collective => plan.max_micro_count(),
            CommScheme::Odc => my.len(),
        };

        for m in 0..m_count {
            let micro = my.get(m).map(|v| v.as_slice()).unwrap_or(&[]);
            if micro.is_empty() {
                idle_participation(&ctx, n_layers, &mut emb_buf, &mut flat_buf, &mut grad_pad)?;
                continue;
            }
            run_microbatch(&ctx, step, micro, &mut emb_buf, &mut flat_buf, &mut grad_pad)?;
        }

        ctx.backend.end_minibatch(dev);

        // ---- server role: sharded AdamW on owned shards ----
        let ntok = ctx.tok_count[step].load(Ordering::SeqCst).max(1) as f32;
        for l in 0..=n_layers {
            let p = &ctx.params.layers[l];
            let g = &mut gshard[..p.shard_len];
            ctx.backend.take_grad_shard(dev, l, g);
            if ctx.cfg.pjrt_shard_ops {
                pjrt_adam_step(&ctx, l, &mut shards[l], g, &mut adam[l], ntok)?;
            } else {
                for x in g.iter_mut() {
                    *x /= ntok;
                }
                adam[l].step(&ctx.cfg.adam, &mut shards[l], g);
            }
            let r = p.shard_range(dev);
            p.buf.write(r.start, &shards[l]);
        }
        ctx.backend.end_step(dev);
        if dev == 0 {
            *ctx.wall[step].lock().unwrap() = t0.elapsed().as_secs_f64();
        }
    }
    Ok(())
}

/// Forward + backward of one packed microbatch through PJRT.
fn run_microbatch(
    ctx: &DeviceCtx,
    step: usize,
    micro: &[usize],
    emb_buf: &mut [f32],
    flat_buf: &mut [f32],
    grad_pad: &mut [f32],
) -> Result<()> {
    let man = &ctx.man;
    let dev = ctx.dev;
    let n_layers = man.n_layers;
    let refs: Vec<&Sample> = micro.iter().map(|&i| &ctx.samples[i]).collect();
    let packed = pack_micro(&refs, &man.seq_buckets)?;
    let s = packed.seq;
    ctx.tok_count[step].fetch_add(packed.real_tokens as u64, Ordering::SeqCst);

    // ---- forward ----
    ctx.backend.gather_params(dev, 0, emb_buf);
    let emb = &emb_buf[..man.embed_params];
    let mut out = ctx.svc.call(
        &format!("embed_fwd_s{s}"),
        vec![Input::F32(emb.to_vec()), Input::I32(packed.tokens.clone())],
    )?;
    let mut x = out.swap_remove(0);

    let mut acts: Vec<Vec<f32>> = Vec::with_capacity(n_layers);
    for l in 1..=n_layers {
        ctx.backend.gather_params(dev, l, flat_buf);
        let flat = &flat_buf[..man.block_params];
        let mut out = ctx.svc.call(
            &format!("block_fwd_s{s}"),
            vec![Input::F32(flat.to_vec()), Input::F32(x.clone()), Input::I32(packed.seg.clone())],
        )?;
        acts.push(std::mem::replace(&mut x, out.swap_remove(0)));
    }

    let out = ctx.svc.call(
        &format!("loss_head_s{s}"),
        vec![
            Input::F32(emb.to_vec()),
            Input::F32(x.clone()),
            Input::I32(packed.targets.clone()),
            Input::F32(packed.mask.clone()),
        ],
    )?;
    let (loss_sum, _ntok, mut dx, demb_head) =
        (out[0][0] as f64, out[1][0] as f64, out[2].clone(), out[3].clone());
    *ctx.loss_sum[step].lock().unwrap() += loss_sum;

    // ---- backward (recompute per layer from saved inputs) ----
    for l in (1..=n_layers).rev() {
        ctx.backend.gather_params(dev, l, flat_buf);
        let flat = &flat_buf[..man.block_params];
        let out = ctx.svc.call(
            &format!("block_bwd_s{s}"),
            vec![
                Input::F32(flat.to_vec()),
                Input::F32(acts[l - 1].clone()),
                Input::I32(packed.seg.clone()),
                Input::F32(dx),
            ],
        )?;
        dx = out[0].clone();
        let p = &ctx.params.layers[l];
        let gp = &mut grad_pad[..p.padded_len()];
        gp[..man.block_params].copy_from_slice(&out[1]);
        gp[man.block_params..].fill(0.0);
        ctx.backend.reduce_grad(dev, l, gp, 1.0);
    }

    // embedding gradient: head (tied weights) + input scatter-add
    let out = ctx.svc.call(
        &format!("embed_bwd_s{s}"),
        vec![Input::I32(packed.tokens.clone()), Input::F32(dx)],
    )?;
    let p = &ctx.params.layers[0];
    let gp = &mut grad_pad[..p.padded_len()];
    for (i, slot) in gp[..man.embed_params].iter_mut().enumerate() {
        *slot = demb_head[i] + out[0][i];
    }
    gp[man.embed_params..].fill(0.0);
    ctx.backend.reduce_grad(dev, 0, gp, 1.0);
    Ok(())
}

/// A padded empty slot under Collective: the device must join exactly the
/// same barrier sequence as a real microbatch — gathers in forward, then
/// gather+reduce per layer in backward, then the embed reduce — with a
/// zero-weight contribution. Under ODC this is a no-op by construction.
fn idle_participation(
    ctx: &DeviceCtx,
    n_layers: usize,
    emb_buf: &mut [f32],
    flat_buf: &mut [f32],
    grad_pad: &mut [f32],
) -> Result<()> {
    if matches!(ctx.cfg.scheme, CommScheme::Odc) {
        return Ok(());
    }
    let dev = ctx.dev;
    ctx.backend.gather_params(dev, 0, emb_buf);
    for l in 1..=n_layers {
        ctx.backend.gather_params(dev, l, flat_buf);
    }
    for l in (1..=n_layers).rev() {
        ctx.backend.gather_params(dev, l, flat_buf);
        let p = &ctx.params.layers[l];
        grad_pad[..p.padded_len()].fill(0.0);
        ctx.backend.reduce_grad(dev, l, &grad_pad[..p.padded_len()], 0.0);
    }
    let p = &ctx.params.layers[0];
    grad_pad[..p.padded_len()].fill(0.0);
    ctx.backend.reduce_grad(dev, 0, &grad_pad[..p.padded_len()], 0.0);
    Ok(())
}

/// Validation path: scale + AdamW through the PJRT chunk kernels
/// (`accum_chunk` is exercised by the scatter-accumulate tests; here we
/// run `adam_chunk` over the shard in fixed-size chunks).
fn pjrt_adam_step(
    ctx: &DeviceCtx,
    _layer: usize,
    p: &mut [f32],
    g: &mut [f32],
    st: &mut AdamState,
    ntok: f32,
) -> Result<()> {
    for x in g.iter_mut() {
        *x /= ntok;
    }
    st.t += 1;
    let (bc1, bc2) = st.bias_corrections(&ctx.cfg.adam);
    let a = &ctx.cfg.adam;
    let hp = vec![a.lr, a.beta1, a.beta2, a.eps, a.weight_decay, bc1, bc2];
    let c = ctx.man.chunk;
    let mut off = 0;
    while off < p.len() {
        let n = c.min(p.len() - off);
        let mut pc = vec![0.0f32; c];
        let mut mc = vec![0.0f32; c];
        let mut vc = vec![0.0f32; c];
        let mut gc = vec![0.0f32; c];
        pc[..n].copy_from_slice(&p[off..off + n]);
        mc[..n].copy_from_slice(&st.m[off..off + n]);
        vc[..n].copy_from_slice(&st.v[off..off + n]);
        gc[..n].copy_from_slice(&g[off..off + n]);
        let out = ctx.svc.call(
            "adam_chunk",
            vec![Input::F32(pc), Input::F32(mc), Input::F32(vc), Input::F32(gc), Input::F32(hp.clone())],
        )?;
        p[off..off + n].copy_from_slice(&out[0][..n]);
        st.m[off..off + n].copy_from_slice(&out[1][..n]);
        st.v[off..off + n].copy_from_slice(&out[2][..n]);
        off += n;
    }
    Ok(())
}
