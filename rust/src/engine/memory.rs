//! Per-device memory model (paper Figure 13): full vs hybrid sharding.
//!
//! Accounts, in bytes per device, for a model of `params` parameters on
//! `devices` devices with `devices_per_node` per node:
//!
//! * parameters + gradients (bf16): sharded across D (full) or G (hybrid)
//! * AdamW state m+v (f32 x2) + f32 master params: always sharded across D
//! * activations: O(tokens · hidden · layers / checkpoint factor) — the
//!   part that is NOT affected by sharding choice.

#[derive(Clone, Copy, Debug)]
pub struct MemoryInputs {
    pub params: f64,
    pub devices: usize,
    pub devices_per_node: usize,
    pub hidden: usize,
    pub layers: usize,
    /// Tokens resident per microbatch.
    pub micro_tokens: usize,
}

#[derive(Clone, Copy, Debug)]
pub struct MemoryBreakdown {
    pub params_bytes: f64,
    pub grads_bytes: f64,
    pub optim_bytes: f64,
    pub activation_bytes: f64,
}

impl MemoryBreakdown {
    pub fn total(&self) -> f64 {
        self.params_bytes + self.grads_bytes + self.optim_bytes + self.activation_bytes
    }

    pub fn gib(&self) -> f64 {
        self.total() / (1u64 << 30) as f64
    }
}

/// Per-device memory under full sharding (ZeRO-3/FSDP).
pub fn full_sharding(m: &MemoryInputs) -> MemoryBreakdown {
    sharded(m, m.devices)
}

/// Per-device memory under hybrid sharding (ZeRO++-style): params/grads
/// sharded only within the node; optimizer state still across all D.
pub fn hybrid_sharding(m: &MemoryInputs) -> MemoryBreakdown {
    sharded(m, m.devices_per_node.min(m.devices))
}

fn sharded(m: &MemoryInputs, pg_shard: usize) -> MemoryBreakdown {
    let d = m.devices as f64;
    let pg = pg_shard as f64;
    // activations with per-layer checkpointing: layer inputs + the live
    // working set of one layer (~4 intermediate tensors)
    let act = (m.layers as f64 + 4.0) * m.micro_tokens as f64 * m.hidden as f64 * 2.0;
    MemoryBreakdown {
        params_bytes: 2.0 * m.params / pg,
        grads_bytes: 2.0 * m.params / pg,
        optim_bytes: 12.0 * m.params / d, // f32 master + m + v
        activation_bytes: act,
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn base() -> MemoryInputs {
        MemoryInputs {
            params: 7.6e9,
            devices: 32,
            devices_per_node: 8,
            hidden: 3584,
            layers: 28,
            micro_tokens: 65_536,
        }
    }

    #[test]
    fn hybrid_uses_more_memory() {
        let m = base();
        let f = full_sharding(&m);
        let h = hybrid_sharding(&m);
        assert!(h.total() > f.total(), "hybrid {h:?} must exceed full {f:?}");
        // ... but only in params+grads, optimizer part identical
        assert_eq!(f.optim_bytes, h.optim_bytes);
        assert!((h.params_bytes / f.params_bytes - 4.0).abs() < 1e-9); // 32/8
    }

    #[test]
    fn single_node_identical() {
        let mut m = base();
        m.devices = 8;
        assert_eq!(full_sharding(&m).total(), hybrid_sharding(&m).total());
    }

    #[test]
    fn activation_independent_of_sharding() {
        let m = base();
        assert_eq!(full_sharding(&m).activation_bytes, hybrid_sharding(&m).activation_bytes);
    }

    #[test]
    fn fits_a100_at_paper_scale() {
        // 7B on 32 GPUs, hybrid: should be < 80 GiB (the paper's point
        // that the trade-off is manageable).
        let h = hybrid_sharding(&base());
        assert!(h.gib() < 80.0, "hybrid 7B/32dev = {:.1} GiB", h.gib());
    }
}
