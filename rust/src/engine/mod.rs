//! The real FSDP training engine: one OS thread per device, sharded
//! parameters/gradients/optimizer state, per-layer gathers, and the
//! pluggable [`crate::comm::CommBackend`] deciding whether layer
//! boundaries are barriers (Collective) or free-running (ODC).
//!
//! All model math executes through the PJRT artifacts (L2/L1); the
//! engine owns only coordination + the sharded AdamW server step.

pub mod memory;
pub mod optimizer;
pub mod packing;
pub mod trainer;

pub use trainer::{train, StepLog, TrainerConfig};
