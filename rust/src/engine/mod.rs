//! The real FSDP training engine: one OS thread per device, sharded
//! parameters/gradients/optimizer state, per-layer gathers, and the
//! pluggable [`crate::comm::CommBackend`] deciding whether layer
//! boundaries are barriers (Collective) or free-running (ODC).
//!
//! All model math executes through the PJRT artifacts (L2/L1); the
//! engine owns only coordination + the sharded AdamW server step.
//!
//! The hot path is zero-copy: every device thread owns a
//! [`bufplan::BufferPlan`] holding its gather cache, gradient staging
//! and recycled activation buffers, and hands tensors to PJRT as shared
//! `Arc` slices instead of cloned `Vec`s.

pub mod bufplan;
pub mod memory;
pub mod optimizer;
pub mod packing;
pub mod trainer;

pub use bufplan::{BufferPlan, SlicePool};
pub use trainer::{train, StepLog, TrainerConfig};
