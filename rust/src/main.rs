//! `odc` — launcher CLI for the ODC reproduction.
//!
//! Subcommands:
//!   sim      — simulate one experiment cell (paper-scale testbed)
//!   train    — REAL FSDP training through PJRT (needs `make artifacts`)
//!   dist     — print dataset length-distribution summaries (Fig 7)
//!   memory   — full vs hybrid sharding memory model (Fig 13)
//!
//! Examples:
//!   odc sim --model 7b --dataset longalign --scheme odc --balancer lb-mini --minibs 4
//!   odc train --preset small --world 4 --steps 40
//!   odc dist

use odc::balance::SplitMode;
use odc::comm::{FaultPlan, TransportKind};
use odc::config::{
    Balancer, CommScheme, Dataset, ExperimentConfig, PaperModel, RunSpec, Sharding, WireDtype,
};
use odc::engine::trainer::{train, TrainerConfig};
use odc::sim::run::{simulate, SimConfig, WireCalib};
use odc::util::cli::Cli;
use std::path::Path;

fn parse_scheme(s: &str) -> anyhow::Result<CommScheme> {
    CommScheme::parse(s).ok_or_else(|| anyhow::anyhow!("unknown scheme `{s}` (odc|collective|hybrid)"))
}

fn parse_balancer(s: &str) -> anyhow::Result<Balancer> {
    Balancer::parse(s)
        .ok_or_else(|| anyhow::anyhow!("unknown balancer `{s}` (local-sort|lb-micro|lb-mini|native|queue)"))
}

/// Parse `--device-speed` — empty for a homogeneous fleet, else a
/// comma-separated relative speed per device ("0.25,1,1,1" = one 4×
/// straggler).
fn parse_device_speed(s: &str) -> anyhow::Result<Vec<f64>> {
    if s.is_empty() {
        return Ok(Vec::new());
    }
    s.split(',')
        .map(|x| {
            x.trim()
                .parse::<f64>()
                .map_err(|_| anyhow::anyhow!("--device-speed expects comma-separated numbers, got `{x}`"))
        })
        .collect()
}

/// Parse a comma-separated list of colon-separated usize tuples of
/// fixed arity ("0:1:2,3:0:0") — the shared grammar of the elastic
/// event flags. Empty input = no events.
fn parse_event_tuples(s: &str, arity: usize, what: &str) -> anyhow::Result<Vec<Vec<usize>>> {
    if s.is_empty() {
        return Ok(Vec::new());
    }
    s.split(',')
        .map(|tuple| {
            let nums: Vec<usize> = tuple
                .trim()
                .split(':')
                .map(|p| p.parse::<usize>())
                .collect::<Result<_, _>>()
                .map_err(|_| anyhow::anyhow!("{what}, got `{tuple}`"))?;
            anyhow::ensure!(nums.len() == arity, "{what}, got `{tuple}`");
            Ok(nums)
        })
        .collect()
}

/// Parse `--fail-at` — comma-separated `device:step:micro` triples
/// ("0:1:2" = device 0 crashes during minibatch 1, immediately before
/// its 3rd pulled microbatch).
fn parse_fail_at(s: &str) -> anyhow::Result<Vec<(usize, usize, usize)>> {
    let tuples = parse_event_tuples(s, 3, "--fail-at expects device:step:micro")?;
    Ok(tuples.into_iter().map(|t| (t[0], t[1], t[2])).collect())
}

/// Parse `--join-at` — comma-separated `device:step` pairs ("3:2" =
/// device 3 sits out steps 0–1 and joins at the step-2 boundary).
fn parse_join_at(s: &str) -> anyhow::Result<Vec<(usize, usize)>> {
    let tuples = parse_event_tuples(s, 2, "--join-at expects device:step")?;
    Ok(tuples.into_iter().map(|t| (t[0], t[1])).collect())
}

/// Parse `--fault-plan` — the ChaosComm lossy-transport grammar
/// ("drop=0.05,dup=0.02,reorder=0.05,seed=7,part=0:2:3"); empty = clean
/// transport. Validation errors use the CLI's standard exit-2 path.
fn parse_fault_plan(s: &str) -> FaultPlan {
    match FaultPlan::parse(s) {
        Ok(p) => p,
        Err(e) => {
            eprintln!("invalid configuration: --fault-plan: {e}");
            std::process::exit(2);
        }
    }
}

/// Parse `--wire-dtype` — FastFold gradient payload precision (`f32` =
/// exact byte image, `bf16` = round-to-nearest-even halves with
/// error-feedback residuals; see docs/wire_precision.md).
fn parse_wire_dtype(s: &str) -> WireDtype {
    match WireDtype::parse(s) {
        Some(d) => d,
        None => {
            eprintln!("invalid configuration: unknown --wire-dtype `{s}` (f32|bf16)");
            std::process::exit(2);
        }
    }
}

/// Parse `--transport` — the WireComm byte transport under the
/// one-sided backends (`inproc` mpsc mailboxes, `shm` lock-free rings,
/// `uds` kernel sockets; see docs/transport.md).
fn parse_transport(s: &str) -> TransportKind {
    match TransportKind::parse(s) {
        Some(k) => k,
        None => {
            eprintln!("invalid configuration: unknown --transport `{s}` (inproc|shm|uds)");
            std::process::exit(2);
        }
    }
}

/// Parse `--seq-split-mode` — `ring` (equal tokens) or `zigzag` (equal
/// predicted cost).
fn parse_split_mode(s: &str) -> SplitMode {
    match SplitMode::parse(s) {
        Some(m) => m,
        None => {
            eprintln!("invalid configuration: unknown --seq-split-mode `{s}` (ring|zigzag)");
            std::process::exit(2);
        }
    }
}

/// Parse `--staleness` — AsyncPS bounded staleness: empty = synchronous
/// barrier, `k` = workers may start a minibatch once every shard server
/// has applied through `t − k` (0 = the async machinery on the
/// synchronous schedule; see docs/asyncps.md).
fn parse_staleness(s: &str) -> Option<usize> {
    if s.is_empty() {
        return None;
    }
    match s.parse::<usize>() {
        Ok(k) => Some(k),
        Err(_) => {
            eprintln!(
                "invalid configuration: --staleness expects a non-negative integer \
                 (empty = synchronous), got `{s}`"
            );
            std::process::exit(2);
        }
    }
}

/// Validate a fully-parsed [`RunSpec`] on the CLI's standard exit-2
/// path — the ONE legality matrix both subcommands consult, so `sim`
/// and `train` cannot drift on which flag combinations are legal.
fn check_spec(spec: &RunSpec, engine: bool) {
    let res = if engine { spec.validate_engine() } else { spec.validate() };
    if let Err(e) = res {
        eprintln!("invalid configuration: {e}");
        std::process::exit(2);
    }
}

fn main() -> anyhow::Result<()> {
    odc::util::logging::init();
    let argv: Vec<String> = std::env::args().skip(1).collect();
    let sub = argv.first().map(|s| s.as_str()).unwrap_or("");
    let rest = argv.get(1..).unwrap_or(&[]).to_vec();

    match sub {
        "sim" => {
            let cli = Cli::new("odc sim", "simulate one experiment cell")
                .opt("model", "1.5b", "1.5b | 7b | 14b | 32b")
                .opt("dataset", "longalign", "longalign | swesmith | aime")
                .opt("scheme", "odc", "odc | collective | hybrid")
                .opt("balancer", "lb-micro", "local-sort | lb-micro | lb-mini | native | queue")
                .opt("minibs", "4", "samples per minibatch per device")
                .opt("devices", "8", "device count")
                .opt("packing-ratio", "1.0", "microbatch budget / max len")
                .opt("max-len", "0", "override max sequence length (0 = dataset default)")
                .opt("steps", "16", "minibatches to simulate")
                .opt("seed", "0", "rng seed")
                .opt("device-speed", "", "per-device relative speed, e.g. 0.25,1,1,1 (empty = uniform)")
                .opt("fail-at", "", "crash events device:step:micro, e.g. 0:1:2 (empty = none)")
                .opt(
                    "fault-plan",
                    "",
                    "lossy transport, e.g. drop=0.05,dup=0.02,seed=7,part=0:2:3 (empty = clean)",
                )
                .opt("seq-split", "0", "split sequences above this fraction of the per-device budget (0 = off)")
                .opt("seq-split-mode", "zigzag", "chunk boundaries: ring (equal tokens) | zigzag (equal cost)")
                .opt("wire-dtype", "bf16", "gradient payload precision: f32 | bf16 (the sim's historical pricing)")
                .opt(
                    "transport",
                    "",
                    "price links from the measured BENCH_wire.json cell for this transport \
                     (shm | uds; empty = the paper's hand-set topology pricing)",
                )
                .opt(
                    "staleness",
                    "",
                    "AsyncPS bounded staleness k: workers run up to k minibatches ahead of the \
                     slowest shard's apply (empty = synchronous barrier)",
                )
                .flag("hybrid", "ZeRO++-style hybrid sharding");
            let a = match cli.parse_from(&rest) {
                Ok(a) => a,
                Err(msg) => {
                    eprintln!("{msg}");
                    std::process::exit(2);
                }
            };
            let dataset = Dataset::parse(a.get("dataset")).ok_or(anyhow::anyhow!("bad dataset"))?;
            let max_len = match a.usize("max-len") {
                0 => match dataset {
                    Dataset::LongAlign => 65_536,
                    Dataset::SweSmith => 32_768,
                    Dataset::Aime => 16_384,
                },
                x => x,
            };
            let exp = ExperimentConfig {
                model: PaperModel::parse(a.get("model")).ok_or(anyhow::anyhow!("bad model"))?,
                dataset,
                scheme: parse_scheme(a.get("scheme"))?,
                balancer: parse_balancer(a.get("balancer"))?,
                sharding: if a.flag("hybrid") { Sharding::Hybrid } else { Sharding::Full },
                minibs: a.usize("minibs"),
                devices: a.usize("devices"),
                devices_per_node: 8,
                packing_ratio: a.f64("packing-ratio"),
                max_len,
                steps: a.usize("steps"),
                seed: a.u64("seed"),
            };
            if let Err(e) = exp.validate() {
                eprintln!("invalid configuration: {e}");
                std::process::exit(2);
            }
            let device_speed = parse_device_speed(a.get("device-speed"))?;
            let fail_at = parse_fail_at(a.get("fail-at"))?;
            let fault_plan = parse_fault_plan(a.get("fault-plan"));
            let seq_split = a.f64("seq-split");
            let wire_dtype = parse_wire_dtype(a.get("wire-dtype"));
            let staleness = parse_staleness(a.get("staleness"));
            // The shared legality matrix (same one the trainer consults).
            let spec = RunSpec {
                scheme: exp.scheme,
                balancer: exp.balancer,
                world: exp.devices,
                steps: exp.steps,
                devices_per_node: exp.devices_per_node,
                device_speed: device_speed.clone(),
                fail_at: fail_at.clone(),
                join_at: Vec::new(),
                fault_plan: fault_plan.clone(),
                seq_split,
                wire_dtype,
                transport: TransportKind::Inproc,
                staleness,
            };
            check_spec(&spec, false);
            // Sim-only: the failover pricing path is split-unaware.
            if seq_split != 0.0 && (!fail_at.is_empty() || !fault_plan.partition.is_empty()) {
                eprintln!(
                    "invalid configuration: --seq-split cannot combine with --fail-at or \
                     partitions in the simulator (the failover pricing path is split-unaware)"
                );
                std::process::exit(2);
            }
            let mut sim_cfg = SimConfig::new(exp);
            sim_cfg.device_speed = device_speed;
            sim_cfg.fail_at = fail_at;
            sim_cfg.fault_plan = fault_plan;
            sim_cfg.seq_split = seq_split;
            sim_cfg.seq_split_mode = parse_split_mode(a.get("seq-split-mode"));
            sim_cfg.wire_dtype = wire_dtype;
            sim_cfg.staleness = staleness;
            if !a.get("transport").is_empty() {
                let kind = parse_transport(a.get("transport"));
                match WireCalib::load(kind) {
                    Ok(c) => sim_cfg.wire_calib = Some(c),
                    Err(e) => {
                        eprintln!(
                            "invalid configuration: --transport {kind} needs a measured \
                             BENCH_wire.json (run `cargo bench --bench wire_calib`): {e}"
                        );
                        std::process::exit(2);
                    }
                }
            }
            let r = simulate(&sim_cfg);
            println!("{}", r.label);
            println!("  samples/s/device : {:.4}", r.samples_per_sec_per_device);
            println!("  bubble rate      : {}", odc::report::pct(r.bubble_rate));
            let total_device_s = r.mean_minibatch_s * r.minibatches as f64 * sim_cfg.exp.devices as f64;
            println!(
                "  device util      : {}   dispatch wait {:.3}s ({} of device-time)",
                odc::report::pct(r.device_utilization),
                r.dispatch_wait_s,
                odc::report::pct(if total_device_s > 0.0 { r.dispatch_wait_s / total_device_s } else { 0.0 })
            );
            if r.wire_bytes > 0 {
                println!(
                    "  hot path         : {:.3} GiB pushed ({} wire)   fold {:.3}s modeled",
                    r.wire_bytes as f64 / (1u64 << 30) as f64,
                    sim_cfg.wire_dtype,
                    r.fold_s
                );
            }
            println!(
                "  mean minibatch   : {:.3}s  ({} minibatches, {} samples)",
                r.mean_minibatch_s, r.minibatches, r.samples
            );
            if let Some(k) = sim_cfg.staleness {
                println!(
                    "  async (k = {k})    : {:.4} samples/s whole-run, staleness p99 {:.1} \
                     (bounded-staleness admission schedule)",
                    r.async_throughput, r.staleness_p99
                );
            }
            if r.hybrid_step_overhead_s > 0.0 {
                println!("  hybrid step ovh  : {:.3} ms/minibatch (cross-node optimizer exchange)", r.hybrid_step_overhead_s * 1e3);
            }
            if !sim_cfg.fail_at.is_empty() {
                println!(
                    "  recovery         : {:.3} ms predicted (state re-read + orphan re-dispatch, {} failure{})",
                    r.recovery_s * 1e3,
                    sim_cfg.fail_at.len(),
                    if sim_cfg.fail_at.len() == 1 { "" } else { "s" }
                );
            }
            if !sim_cfg.fault_plan.is_noop() {
                println!(
                    "  fault pricing    : {} retries, {} retransmitted bytes, {} escalation{}",
                    r.retries,
                    r.retransmitted_bytes,
                    r.escalations,
                    if r.escalations == 1 { "" } else { "s" }
                );
                if r.escalations > 0 {
                    println!(
                        "  escalation       : partitioned links became derived fail-stops; recovery {:.3} ms",
                        r.recovery_s * 1e3
                    );
                }
            }
        }
        "train" => {
            let cli = Cli::new("odc train", "real FSDP training through PJRT")
                .opt("preset", "small", "artifact preset under artifacts/")
                .opt("world", "4", "device threads")
                .opt("minibs", "4", "samples per device per minibatch")
                .opt("steps", "40", "optimizer steps")
                .opt("scheme", "odc", "odc | collective | hybrid")
                .opt("devices-per-node", "0", "hybrid node-group size (0 = single group)")
                .opt("balancer", "lb-mini", "local-sort | lb-micro | lb-mini | queue")
                .opt("lr", "0.003", "AdamW lr")
                .opt("seed", "0", "rng seed")
                .opt("device-speed", "", "per-device relative speed, e.g. 0.25,1 (empty = uniform)")
                .opt("fail-at", "", "crash events device:step:micro, e.g. 0:1:2 (empty = none)")
                .opt("join-at", "", "join events device:step, e.g. 3:2 (empty = none)")
                .opt(
                    "fault-plan",
                    "",
                    "lossy transport, e.g. drop=0.05,dup=0.02,seed=7,part=0:2:3 (empty = clean)",
                )
                .opt("seq-split", "0", "split sequences above this fraction of the per-device budget (0 = off)")
                .opt("seq-split-mode", "zigzag", "chunk boundaries: ring (equal tokens) | zigzag (equal cost)")
                .opt("wire-dtype", "f32", "gradient payload precision: f32 (bit-exact) | bf16 (half the wire bytes)")
                .opt("transport", "inproc", "mailbox byte transport: inproc | shm (ring buffers) | uds (sockets)")
                .opt(
                    "staleness",
                    "",
                    "AsyncPS bounded staleness k: workers run up to k minibatches ahead of the \
                     slowest shard's apply (empty = synchronous barrier; 0 = bit-identical async)",
                )
                .flag("pjrt-shard-ops", "run adam through the PJRT chunk kernel");
            let a = match cli.parse_from(&rest) {
                Ok(a) => a,
                Err(msg) => {
                    eprintln!("{msg}");
                    std::process::exit(2);
                }
            };
            let dir = Path::new(env!("CARGO_MANIFEST_DIR")).join("artifacts").join(a.get("preset"));
            anyhow::ensure!(dir.join("manifest.json").exists(), "no artifacts at {dir:?}; run `make artifacts`");
            let mut cfg = TrainerConfig::new(dir);
            cfg.world = a.usize("world");
            cfg.minibs = a.usize("minibs");
            cfg.steps = a.usize("steps");
            cfg.scheme = parse_scheme(a.get("scheme"))?;
            cfg.devices_per_node = a.usize("devices-per-node");
            cfg.balancer = parse_balancer(a.get("balancer"))?;
            cfg.adam.lr = a.f64("lr") as f32;
            cfg.seed = a.u64("seed");
            cfg.pjrt_shard_ops = a.flag("pjrt-shard-ops");
            cfg.device_speed = parse_device_speed(a.get("device-speed"))?;
            cfg.fail_at = parse_fail_at(a.get("fail-at"))?;
            cfg.join_at = parse_join_at(a.get("join-at"))?;
            cfg.fault_plan = parse_fault_plan(a.get("fault-plan"));
            cfg.seq_split = a.f64("seq-split");
            cfg.seq_split_mode = parse_split_mode(a.get("seq-split-mode"));
            cfg.wire_dtype = parse_wire_dtype(a.get("wire-dtype"));
            cfg.transport = parse_transport(a.get("transport"));
            cfg.staleness = parse_staleness(a.get("staleness"));
            // The shared legality matrix plus the engine-only codec
            // constraint — `train` re-validates, but catching it here
            // keeps the CLI's exit-2 contract for config errors.
            check_spec(&cfg.runspec(), true);
            let lossy = !cfg.fault_plan.is_noop();
            let elastic = !cfg.fail_at.is_empty()
                || !cfg.join_at.is_empty()
                || !cfg.fault_plan.partition.is_empty();
            let run = train(&cfg)?;
            for log in &run.logs {
                println!(
                    "step {:>4}  loss {:>8.4}  tokens {:>8}  wall {:>7.3}s",
                    log.step, log.loss, log.tokens, log.wall_s
                );
            }
            if run.wire_bytes > 0 {
                println!(
                    "hotpath  wire_bytes {}  ({} wire)  fold_s {:.6}",
                    run.wire_bytes, cfg.wire_dtype, run.fold_s
                );
            }
            if let Some(k) = cfg.staleness {
                println!(
                    "staleness  max {}  p99 {}  (bounded-staleness admission, k = {k})",
                    run.staleness_max, run.staleness_p99
                );
            }
            if elastic {
                println!(
                    "recovery_s {:.6}  (measured ElasticWorld recovery overhead: orphan flushes, \
                     shard adoption, join refresh)",
                    run.recovery_s
                );
            }
            if lossy {
                println!(
                    "fault_stats  retries {}  retransmitted_bytes {}  escalations {}",
                    run.retries, run.retransmitted_bytes, run.escalations
                );
            }
        }
        // internal: one endpoint rank of the multi-process wire smoke
        // (spawned by `wire-smoke` — every byte crosses kernel sockets
        // between genuinely separate OS processes)
        "wire-worker" => {
            let cli = Cli::new("odc wire-worker", "internal: one spawn_world endpoint rank")
                .opt("rank", "0", "this process's rank")
                .opt("world", "4", "total ranks")
                .opt("dir", "", "shared rendezvous directory");
            let a = match cli.parse_from(&rest) {
                Ok(a) => a,
                Err(msg) => {
                    eprintln!("{msg}");
                    std::process::exit(2);
                }
            };
            let code =
                odc::runtime::spawn_world::worker_main(a.usize("rank"), a.usize("world"), a.get("dir"));
            std::process::exit(code);
        }
        // CI hang detector: spawn `world` OS-process workers that run a
        // deterministic scatter-accumulate over UDS and bit-check the
        // reduction (see runtime::spawn_world)
        "wire-smoke" => {
            let cli = Cli::new("odc wire-smoke", "multi-process socket-transport smoke test")
                .opt("world", "4", "worker OS processes")
                .opt("timeout-s", "120", "kill + fail if workers outlive this deadline");
            let a = match cli.parse_from(&rest) {
                Ok(a) => a,
                Err(msg) => {
                    eprintln!("{msg}");
                    std::process::exit(2);
                }
            };
            let code = odc::runtime::spawn_world::smoke_main(a.usize("world"), a.u64("timeout-s"));
            std::process::exit(code);
        }
        "dist" => {
            use odc::data::distributions::{sample_lengths, summarize};
            use odc::util::rng::Rng;
            for ds in [Dataset::LongAlign, Dataset::SweSmith, Dataset::Aime] {
                let mut rng = Rng::new(7);
                let lens = sample_lengths(ds, None, 20_000, &mut rng);
                let (p50, p90, p99, max, mean) = summarize(&lens);
                println!("{ds:<10} p50={p50:<7.0} p90={p90:<7.0} p99={p99:<7.0} max={max:<7} mean={mean:.0}");
            }
        }
        "memory" => {
            use odc::engine::memory::{full_sharding, hybrid_sharding, MemoryInputs};
            for model in PaperModel::all() {
                let (layers, hidden, params) = model.shape();
                let devices = ExperimentConfig::paper_devices(model);
                let m = MemoryInputs { params, devices, devices_per_node: 8, hidden, layers, micro_tokens: 8192 };
                println!(
                    "{model:<5} {devices:>2} devices: full {:>6.1} GiB | hybrid {:>6.1} GiB",
                    full_sharding(&m).gib(),
                    hybrid_sharding(&m).gib()
                );
            }
        }
        _ => {
            println!("odc {} — Revisiting Parameter Server in LLM Post-Training", odc::version());
            println!("\nsubcommands: sim | train | dist | memory");
            println!("try: odc sim --help, odc train --help");
            println!("benches (one per paper table/figure): cargo bench");
        }
    }
    Ok(())
}
