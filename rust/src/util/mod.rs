//! Foundational substrates built in-repo (crates.io is unreachable in
//! this environment; see DESIGN.md §3.1 and §8 for the substitutions).

pub mod bench;
pub mod cli;
pub mod json;
pub mod logging;
pub mod prop;
pub mod rng;
pub mod stats;
pub mod threadpool;
