//! Mini property-testing substrate (proptest is unreachable offline).
//!
//! `check(name, cases, gen, prop)` runs `cases` random inputs through
//! `prop`; on failure it performs greedy shrinking via the value's
//! `Shrink` impl and panics with the minimal counterexample. The Python
//! side uses real `hypothesis`; this covers the Rust invariants listed in
//! DESIGN.md §6.

use super::rng::Rng;
use std::fmt::Debug;

/// Types that can propose smaller versions of themselves.
pub trait Shrink: Sized {
    fn shrink(&self) -> Vec<Self>;
}

impl Shrink for usize {
    fn shrink(&self) -> Vec<Self> {
        let mut out = Vec::new();
        if *self > 0 {
            out.push(self / 2);
            out.push(self - 1);
        }
        out
    }
}

impl Shrink for u64 {
    fn shrink(&self) -> Vec<Self> {
        let mut out = Vec::new();
        if *self > 0 {
            out.push(self / 2);
            out.push(self - 1);
        }
        out
    }
}

impl<T: Shrink + Clone> Shrink for Vec<T> {
    fn shrink(&self) -> Vec<Self> {
        let mut out = Vec::new();
        if self.is_empty() {
            return out;
        }
        // halves
        out.push(self[..self.len() / 2].to_vec());
        out.push(self[self.len() / 2..].to_vec());
        // drop one element
        if self.len() <= 16 {
            for i in 0..self.len() {
                let mut v = self.clone();
                v.remove(i);
                out.push(v);
            }
        }
        // shrink one element
        for i in 0..self.len().min(8) {
            for s in self[i].shrink() {
                let mut v = self.clone();
                v[i] = s;
                out.push(v);
            }
        }
        out
    }
}

impl<A: Shrink + Clone, B: Shrink + Clone> Shrink for (A, B) {
    fn shrink(&self) -> Vec<Self> {
        let mut out: Vec<Self> = self.0.shrink().into_iter().map(|a| (a, self.1.clone())).collect();
        out.extend(self.1.shrink().into_iter().map(|b| (self.0.clone(), b)));
        out
    }
}

/// Run a property over `cases` random inputs; shrink + panic on failure.
pub fn check<T, G, P>(name: &str, cases: usize, mut gen: G, prop: P)
where
    T: Shrink + Clone + Debug,
    G: FnMut(&mut Rng) -> T,
    P: Fn(&T) -> Result<(), String>,
{
    let mut rng = Rng::new(0x0DC_5EED);
    for case in 0..cases {
        let input = gen(&mut rng);
        if let Err(msg) = prop(&input) {
            let (min_input, min_msg) = shrink_loop(input, msg, &prop);
            panic!(
                "property `{name}` failed (case {case}/{cases})\n  counterexample: {min_input:?}\n  reason: {min_msg}"
            );
        }
    }
}

fn shrink_loop<T, P>(mut input: T, mut msg: String, prop: &P) -> (T, String)
where
    T: Shrink + Clone + Debug,
    P: Fn(&T) -> Result<(), String>,
{
    // Greedy descent, bounded to avoid pathological loops.
    'outer: for _ in 0..200 {
        for cand in input.shrink() {
            if let Err(m) = prop(&cand) {
                input = cand;
                msg = m;
                continue 'outer;
            }
        }
        break;
    }
    (input, msg)
}

/// Generator helpers.
pub fn vec_of<T>(rng: &mut Rng, min_len: usize, max_len: usize, mut f: impl FnMut(&mut Rng) -> T) -> Vec<T> {
    let n = rng.range(min_len as i64, max_len as i64) as usize;
    (0..n).map(|_| f(rng)).collect()
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn passing_property_passes() {
        check("rev-rev", 50, |r| vec_of(r, 0, 20, |r| r.below(100) as usize), |v| {
            let mut w = v.clone();
            w.reverse();
            w.reverse();
            if w == *v {
                Ok(())
            } else {
                Err("rev∘rev != id".into())
            }
        });
    }

    #[test]
    #[should_panic(expected = "property `always-small`")]
    fn failing_property_shrinks() {
        check("always-small", 200, |r| vec_of(r, 0, 30, |r| r.below(1000) as usize), |v| {
            if v.iter().sum::<usize>() < 500 {
                Ok(())
            } else {
                Err(format!("sum {} too big", v.iter().sum::<usize>()))
            }
        });
    }

    #[test]
    fn shrink_usize_descends() {
        assert!(10usize.shrink().iter().all(|&s| s < 10));
        assert!(0usize.shrink().is_empty());
    }
}
