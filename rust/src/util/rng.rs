//! Deterministic RNG substrate (SplitMix64 seeding + Xoshiro256**).
//!
//! crates.io is unreachable in this environment (see DESIGN.md §3.1), so
//! the usual `rand` stack is replaced by this small, well-known pair of
//! generators. Determinism matters more than statistical exotica here:
//! every experiment (dataset draw, packing shuffle, simulator jitter) is
//! keyed by an explicit seed so paper tables regenerate bit-identically.

/// SplitMix64: used to expand a single u64 seed into generator state.
#[inline]
fn splitmix64(state: &mut u64) -> u64 {
    *state = state.wrapping_add(0x9E3779B97F4A7C15);
    let mut z = *state;
    z = (z ^ (z >> 30)).wrapping_mul(0xBF58476D1CE4E5B9);
    z = (z ^ (z >> 27)).wrapping_mul(0x94D049BB133111EB);
    z ^ (z >> 31)
}

/// Xoshiro256** — fast, high-quality, 2^256-1 period.
#[derive(Clone, Debug)]
pub struct Rng {
    s: [u64; 4],
}

impl Rng {
    pub fn new(seed: u64) -> Self {
        let mut sm = seed;
        let mut s = [0u64; 4];
        for slot in &mut s {
            *slot = splitmix64(&mut sm);
        }
        Rng { s }
    }

    /// Derive an independent stream (e.g. per-device, per-experiment).
    pub fn fork(&mut self, stream: u64) -> Rng {
        Rng::new(self.next_u64() ^ stream.wrapping_mul(0x9E3779B97F4A7C15))
    }

    #[inline]
    pub fn next_u64(&mut self) -> u64 {
        let result = self.s[1].wrapping_mul(5).rotate_left(7).wrapping_mul(9);
        let t = self.s[1] << 17;
        self.s[2] ^= self.s[0];
        self.s[3] ^= self.s[1];
        self.s[1] ^= self.s[2];
        self.s[0] ^= self.s[3];
        self.s[2] ^= t;
        self.s[3] = self.s[3].rotate_left(45);
        result
    }

    /// Uniform in [0, 1).
    #[inline]
    pub fn f64(&mut self) -> f64 {
        (self.next_u64() >> 11) as f64 * (1.0 / (1u64 << 53) as f64)
    }

    /// Uniform integer in [0, n) without modulo bias (Lemire).
    #[inline]
    pub fn below(&mut self, n: u64) -> u64 {
        debug_assert!(n > 0);
        let mut x = self.next_u64();
        let mut m = (x as u128) * (n as u128);
        let mut l = m as u64;
        if l < n {
            let t = n.wrapping_neg() % n;
            while l < t {
                x = self.next_u64();
                m = (x as u128) * (n as u128);
                l = m as u64;
            }
        }
        (m >> 64) as u64
    }

    /// Uniform in [lo, hi] inclusive.
    pub fn range(&mut self, lo: i64, hi: i64) -> i64 {
        debug_assert!(lo <= hi);
        lo + self.below((hi - lo + 1) as u64) as i64
    }

    /// Standard normal via Box–Muller.
    pub fn normal(&mut self) -> f64 {
        let u1 = (1.0 - self.f64()).max(f64::MIN_POSITIVE);
        let u2 = self.f64();
        (-2.0 * u1.ln()).sqrt() * (2.0 * std::f64::consts::PI * u2).cos()
    }

    /// Log-normal with the given mu/sigma of the underlying normal.
    pub fn lognormal(&mut self, mu: f64, sigma: f64) -> f64 {
        (mu + sigma * self.normal()).exp()
    }

    /// Fisher–Yates shuffle.
    pub fn shuffle<T>(&mut self, xs: &mut [T]) {
        for i in (1..xs.len()).rev() {
            let j = self.below((i + 1) as u64) as usize;
            xs.swap(i, j);
        }
    }

    /// Sample k distinct indices from [0, n) (k <= n), order randomized.
    pub fn sample_indices(&mut self, n: usize, k: usize) -> Vec<usize> {
        assert!(k <= n);
        let mut idx: Vec<usize> = (0..n).collect();
        for i in 0..k {
            let j = i + self.below((n - i) as u64) as usize;
            idx.swap(i, j);
        }
        idx.truncate(k);
        idx
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn deterministic() {
        let mut a = Rng::new(42);
        let mut b = Rng::new(42);
        for _ in 0..100 {
            assert_eq!(a.next_u64(), b.next_u64());
        }
    }

    #[test]
    fn different_seeds_differ() {
        let (mut a, mut b) = (Rng::new(1), Rng::new(2));
        assert_ne!(
            (0..8).map(|_| a.next_u64()).collect::<Vec<_>>(),
            (0..8).map(|_| b.next_u64()).collect::<Vec<_>>()
        );
    }

    #[test]
    fn f64_in_unit_interval() {
        let mut r = Rng::new(7);
        for _ in 0..10_000 {
            let x = r.f64();
            assert!((0.0..1.0).contains(&x));
        }
    }

    #[test]
    fn below_unbiased_coverage() {
        let mut r = Rng::new(3);
        let mut seen = [0usize; 10];
        for _ in 0..50_000 {
            seen[r.below(10) as usize] += 1;
        }
        for &c in &seen {
            assert!((3500..6500).contains(&c), "bucket count {c} out of range");
        }
    }

    #[test]
    fn normal_moments() {
        let mut r = Rng::new(11);
        let n = 200_000;
        let (mut sum, mut sum2) = (0.0, 0.0);
        for _ in 0..n {
            let x = r.normal();
            sum += x;
            sum2 += x * x;
        }
        let mean = sum / n as f64;
        let var = sum2 / n as f64 - mean * mean;
        assert!(mean.abs() < 0.02, "mean {mean}");
        assert!((var - 1.0).abs() < 0.03, "var {var}");
    }

    #[test]
    fn shuffle_is_permutation() {
        let mut r = Rng::new(5);
        let mut v: Vec<u32> = (0..100).collect();
        r.shuffle(&mut v);
        let mut sorted = v.clone();
        sorted.sort_unstable();
        assert_eq!(sorted, (0..100).collect::<Vec<_>>());
    }

    #[test]
    fn sample_indices_distinct() {
        let mut r = Rng::new(9);
        let idx = r.sample_indices(50, 20);
        let mut s = idx.clone();
        s.sort_unstable();
        s.dedup();
        assert_eq!(s.len(), 20);
        assert!(idx.iter().all(|&i| i < 50));
    }

    #[test]
    fn fork_streams_independent() {
        let mut root = Rng::new(1);
        let mut a = root.fork(0);
        let mut b = root.fork(1);
        assert_ne!(a.next_u64(), b.next_u64());
    }
}
