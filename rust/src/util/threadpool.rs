//! Scoped worker-pool substrate (tokio is unreachable offline; the
//! training engine wants deterministic OS threads anyway — one per
//! simulated device — and the simulator sweeps want simple fan-out).

use std::sync::mpsc;
use std::sync::{Arc, Mutex};
use std::thread;

/// Run `jobs` closures on up to `workers` threads; returns results in
/// submission order. Panics in jobs propagate.
pub fn scoped_map<T, F>(workers: usize, jobs: Vec<F>) -> Vec<T>
where
    T: Send,
    F: FnOnce() -> T + Send,
{
    let n = jobs.len();
    if n == 0 {
        return Vec::new();
    }
    let workers = workers.max(1).min(n);
    let queue: Arc<Mutex<Vec<(usize, F)>>> = Arc::new(Mutex::new(jobs.into_iter().enumerate().rev().collect()));
    let (tx, rx) = mpsc::channel::<(usize, thread::Result<T>)>();

    thread::scope(|s| {
        for _ in 0..workers {
            let queue = Arc::clone(&queue);
            let tx = tx.clone();
            s.spawn(move || loop {
                let job = queue.lock().unwrap().pop();
                match job {
                    Some((i, f)) => {
                        let r = std::panic::catch_unwind(std::panic::AssertUnwindSafe(f));
                        if tx.send((i, r)).is_err() {
                            return;
                        }
                    }
                    None => return,
                }
            });
        }
        drop(tx);
        let mut out: Vec<Option<T>> = (0..n).map(|_| None).collect();
        for (i, r) in rx {
            match r {
                Ok(v) => out[i] = Some(v),
                // Re-raise with the job index: a bare resume_unwind here
                // surfaces as the unrelated "job did not report" expect
                // below, making pool-amplified failures (e.g. chaos-test
                // assertions) unattributable to the job that died.
                Err(p) => {
                    let msg = if let Some(s) = p.downcast_ref::<&str>() {
                        (*s).to_string()
                    } else if let Some(s) = p.downcast_ref::<String>() {
                        s.clone()
                    } else {
                        "non-string panic payload".to_string()
                    };
                    panic!("job {i} panicked: {msg}");
                }
            }
        }
        out.into_iter().map(|o| o.expect("job did not report")).collect()
    })
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn preserves_order() {
        let jobs: Vec<_> = (0..20).map(|i| move || i * i).collect();
        let out = scoped_map(4, jobs);
        assert_eq!(out, (0..20).map(|i| i * i).collect::<Vec<_>>());
    }

    #[test]
    fn single_worker_ok() {
        let out = scoped_map(1, vec![|| 1, || 2]);
        assert_eq!(out, vec![1, 2]);
    }

    #[test]
    #[should_panic(expected = "boom")]
    fn panics_propagate() {
        let jobs: Vec<Box<dyn FnOnce() -> i32 + Send>> = vec![Box::new(|| 1), Box::new(|| panic!("boom"))];
        scoped_map(2, jobs);
    }

    #[test]
    #[should_panic(expected = "job 1 panicked: boom")]
    fn panics_carry_the_job_index() {
        let jobs: Vec<Box<dyn FnOnce() -> i32 + Send>> =
            vec![Box::new(|| 1), Box::new(|| panic!("boom")), Box::new(|| 3)];
        scoped_map(1, jobs);
    }
}
