//! Bench harness substrate (criterion is unreachable offline).
//!
//! `cargo bench` targets set `harness = false` and drive this runner:
//! warmup, timed iterations, and a summary line with mean / p50 / p95 /
//! std. Report emitters in `report` turn grouped results into the
//! markdown tables mirroring the paper's tables/figures.

use super::stats::{percentile, Summary};
use std::time::Instant;

#[derive(Clone, Debug)]
pub struct BenchResult {
    pub name: String,
    pub iters: usize,
    pub mean_ns: f64,
    pub p50_ns: f64,
    pub p95_ns: f64,
    pub std_ns: f64,
}

impl BenchResult {
    pub fn mean_ms(&self) -> f64 {
        self.mean_ns / 1e6
    }

    pub fn line(&self) -> String {
        format!(
            "{:<44} {:>10.3} ms/iter  (p50 {:>9.3}, p95 {:>9.3}, ±{:>8.3}, n={})",
            self.name,
            self.mean_ns / 1e6,
            self.p50_ns / 1e6,
            self.p95_ns / 1e6,
            self.std_ns / 1e6,
            self.iters
        )
    }
}

pub struct Bencher {
    pub warmup: usize,
    pub iters: usize,
}

impl Default for Bencher {
    fn default() -> Self {
        // Small defaults: single-core CI box; benches are about *relative*
        // numbers. Override via ODC_BENCH_ITERS for longer runs.
        let iters = std::env::var("ODC_BENCH_ITERS").ok().and_then(|v| v.parse().ok()).unwrap_or(10);
        Bencher { warmup: 2, iters }
    }
}

impl Bencher {
    pub fn run<T>(&self, name: &str, mut f: impl FnMut() -> T) -> BenchResult {
        for _ in 0..self.warmup {
            std::hint::black_box(f());
        }
        let mut samples = Vec::with_capacity(self.iters);
        for _ in 0..self.iters {
            let t0 = Instant::now();
            std::hint::black_box(f());
            samples.push(t0.elapsed().as_nanos() as f64);
        }
        let s = Summary::from_slice(&samples);
        let r = BenchResult {
            name: name.to_string(),
            iters: self.iters,
            mean_ns: s.mean(),
            p50_ns: percentile(&samples, 50.0),
            p95_ns: percentile(&samples, 95.0),
            std_ns: s.std(),
        };
        println!("{}", r.line());
        r
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn measures_something() {
        let b = Bencher { warmup: 1, iters: 5 };
        let r = b.run("spin", || {
            let mut x = 0u64;
            for i in 0..10_000 {
                x = x.wrapping_add(i);
            }
            x
        });
        assert!(r.mean_ns > 0.0);
        assert_eq!(r.iters, 5);
    }
}
