//! Summary statistics + timing helpers shared by benches, the simulator
//! and the report emitters.

use std::time::{Duration, Instant};

/// Online summary of a sample set (Welford for mean/variance).
#[derive(Clone, Debug, Default)]
pub struct Summary {
    pub n: usize,
    mean: f64,
    m2: f64,
    pub min: f64,
    pub max: f64,
}

impl Summary {
    pub fn new() -> Self {
        Summary { n: 0, mean: 0.0, m2: 0.0, min: f64::INFINITY, max: f64::NEG_INFINITY }
    }

    pub fn add(&mut self, x: f64) {
        self.n += 1;
        let d = x - self.mean;
        self.mean += d / self.n as f64;
        self.m2 += d * (x - self.mean);
        self.min = self.min.min(x);
        self.max = self.max.max(x);
    }

    pub fn mean(&self) -> f64 {
        self.mean
    }

    pub fn var(&self) -> f64 {
        if self.n < 2 {
            0.0
        } else {
            self.m2 / (self.n - 1) as f64
        }
    }

    pub fn std(&self) -> f64 {
        self.var().sqrt()
    }

    pub fn from_slice(xs: &[f64]) -> Self {
        let mut s = Summary::new();
        for &x in xs {
            s.add(x);
        }
        s
    }
}

/// Percentile of a sample (linear interpolation); q in [0, 100].
pub fn percentile(xs: &[f64], q: f64) -> f64 {
    assert!(!xs.is_empty());
    let mut v = xs.to_vec();
    v.sort_by(|a, b| a.partial_cmp(b).unwrap());
    let pos = (q / 100.0) * (v.len() - 1) as f64;
    let (lo, hi) = (pos.floor() as usize, pos.ceil() as usize);
    if lo == hi {
        v[lo]
    } else {
        let frac = pos - lo as f64;
        v[lo] * (1.0 - frac) + v[hi] * frac
    }
}

/// Fixed-width histogram over [lo, hi) with `bins` buckets.
pub fn histogram(xs: &[f64], lo: f64, hi: f64, bins: usize) -> Vec<usize> {
    let mut h = vec![0usize; bins];
    let w = (hi - lo) / bins as f64;
    for &x in xs {
        if x >= lo && x < hi {
            h[((x - lo) / w) as usize] += 1;
        } else if x == hi {
            h[bins - 1] += 1;
        }
    }
    h
}

/// Time a closure, returning (result, elapsed).
pub fn timed<T>(f: impl FnOnce() -> T) -> (T, Duration) {
    let t0 = Instant::now();
    let r = f();
    (r, t0.elapsed())
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn summary_basic() {
        let s = Summary::from_slice(&[1.0, 2.0, 3.0, 4.0]);
        assert_eq!(s.n, 4);
        assert!((s.mean() - 2.5).abs() < 1e-12);
        assert!((s.var() - 5.0 / 3.0).abs() < 1e-12);
        assert_eq!((s.min, s.max), (1.0, 4.0));
    }

    #[test]
    fn percentiles() {
        let xs: Vec<f64> = (1..=100).map(|i| i as f64).collect();
        assert!((percentile(&xs, 50.0) - 50.5).abs() < 1e-9);
        assert_eq!(percentile(&xs, 0.0), 1.0);
        assert_eq!(percentile(&xs, 100.0), 100.0);
    }

    #[test]
    fn histogram_counts() {
        let h = histogram(&[0.1, 0.2, 0.55, 0.9, 1.0], 0.0, 1.0, 2);
        assert_eq!(h, vec![2, 3]);
    }
}
