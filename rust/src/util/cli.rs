//! Small declarative CLI substrate (clap is unreachable offline).
//!
//! Supports `--name value`, `--name=value`, boolean `--flag`, and a
//! subcommand word. Every binary/example in this repo funnels through
//! this so `--help` output is uniform.

use std::collections::BTreeMap;

#[derive(Clone, Debug)]
pub struct ArgSpec {
    pub name: &'static str,
    pub help: &'static str,
    pub default: Option<String>,
    pub is_flag: bool,
}

#[derive(Debug)]
pub struct Args {
    values: BTreeMap<String, String>,
    flags: Vec<String>,
    pub positional: Vec<String>,
}

pub struct Cli {
    pub name: &'static str,
    pub about: &'static str,
    specs: Vec<ArgSpec>,
}

impl Cli {
    pub fn new(name: &'static str, about: &'static str) -> Self {
        Cli { name, about, specs: Vec::new() }
    }

    pub fn opt(mut self, name: &'static str, default: &str, help: &'static str) -> Self {
        self.specs.push(ArgSpec { name, help, default: Some(default.to_string()), is_flag: false });
        self
    }

    pub fn req(mut self, name: &'static str, help: &'static str) -> Self {
        self.specs.push(ArgSpec { name, help, default: None, is_flag: false });
        self
    }

    pub fn flag(mut self, name: &'static str, help: &'static str) -> Self {
        self.specs.push(ArgSpec { name, help, default: None, is_flag: true });
        self
    }

    pub fn usage(&self) -> String {
        let mut s = format!("{} — {}\n\nOptions:\n", self.name, self.about);
        for spec in &self.specs {
            let d = match (&spec.default, spec.is_flag) {
                (_, true) => String::new(),
                (Some(d), _) if !d.is_empty() => format!(" [default: {d}]"),
                _ => " (required)".to_string(),
            };
            s.push_str(&format!("  --{:<18} {}{}\n", spec.name, spec.help, d));
        }
        s
    }

    /// Parse from an explicit token list (testable); exits on --help.
    pub fn parse_from(&self, tokens: &[String]) -> Result<Args, String> {
        let mut values = BTreeMap::new();
        let mut flags = Vec::new();
        let mut positional = Vec::new();
        let mut i = 0;
        while i < tokens.len() {
            let t = &tokens[i];
            if t == "--help" || t == "-h" {
                return Err(self.usage());
            }
            if let Some(raw) = t.strip_prefix("--") {
                let (name, inline) = match raw.split_once('=') {
                    Some((n, v)) => (n.to_string(), Some(v.to_string())),
                    None => (raw.to_string(), None),
                };
                let spec = self
                    .specs
                    .iter()
                    .find(|s| s.name == name)
                    .ok_or_else(|| format!("unknown option --{name}\n\n{}", self.usage()))?;
                if spec.is_flag {
                    if inline.is_some() {
                        return Err(format!("--{name} is a flag, takes no value"));
                    }
                    flags.push(name);
                } else {
                    let v = match inline {
                        Some(v) => v,
                        None => {
                            i += 1;
                            tokens.get(i).cloned().ok_or_else(|| format!("--{name} needs a value"))?
                        }
                    };
                    values.insert(name, v);
                }
            } else {
                positional.push(t.clone());
            }
            i += 1;
        }
        // fill defaults / check required
        for spec in &self.specs {
            if spec.is_flag || values.contains_key(spec.name) {
                continue;
            }
            match &spec.default {
                Some(d) => {
                    values.insert(spec.name.to_string(), d.clone());
                }
                None => return Err(format!("missing required --{}\n\n{}", spec.name, self.usage())),
            }
        }
        Ok(Args { values, flags, positional })
    }

    /// Parse process args; prints usage and exits on error or --help.
    pub fn parse(&self) -> Args {
        let tokens: Vec<String> = std::env::args().skip(1).collect();
        match self.parse_from(&tokens) {
            Ok(a) => a,
            Err(msg) => {
                eprintln!("{msg}");
                std::process::exit(if msg.starts_with(self.name) { 0 } else { 2 });
            }
        }
    }
}

impl Args {
    pub fn get(&self, name: &str) -> &str {
        self.values.get(name).map(|s| s.as_str()).unwrap_or("")
    }

    pub fn usize(&self, name: &str) -> usize {
        self.get(name).parse().unwrap_or_else(|_| panic!("--{name} expects an integer, got `{}`", self.get(name)))
    }

    pub fn u64(&self, name: &str) -> u64 {
        self.get(name).parse().unwrap_or_else(|_| panic!("--{name} expects an integer, got `{}`", self.get(name)))
    }

    pub fn f64(&self, name: &str) -> f64 {
        self.get(name).parse().unwrap_or_else(|_| panic!("--{name} expects a number, got `{}`", self.get(name)))
    }

    pub fn flag(&self, name: &str) -> bool {
        self.flags.iter().any(|f| f == name)
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn cli() -> Cli {
        Cli::new("t", "test").opt("devices", "8", "device count").req("preset", "model preset").flag("verbose", "chatty")
    }

    fn toks(s: &[&str]) -> Vec<String> {
        s.iter().map(|x| x.to_string()).collect()
    }

    #[test]
    fn parses_values_and_defaults() {
        let a = cli().parse_from(&toks(&["--preset", "tiny"])).unwrap();
        assert_eq!(a.usize("devices"), 8);
        assert_eq!(a.get("preset"), "tiny");
        assert!(!a.flag("verbose"));
    }

    #[test]
    fn equals_syntax_and_flags() {
        let a = cli().parse_from(&toks(&["--preset=small", "--devices=4", "--verbose", "run"])).unwrap();
        assert_eq!(a.usize("devices"), 4);
        assert!(a.flag("verbose"));
        assert_eq!(a.positional, vec!["run"]);
    }

    #[test]
    fn missing_required_errors() {
        assert!(cli().parse_from(&toks(&[])).is_err());
    }

    #[test]
    fn unknown_option_errors() {
        assert!(cli().parse_from(&toks(&["--preset", "t", "--bogus", "1"])).is_err());
    }
}
