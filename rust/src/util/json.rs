//! Minimal JSON parser/serializer substrate (serde is unreachable here).
//!
//! Used for: the AOT artifact manifest, experiment configs, and
//! machine-readable experiment output. Supports the full JSON grammar
//! except `\u` surrogate pairs are passed through unvalidated.

use std::collections::BTreeMap;
use std::fmt;

#[derive(Clone, Debug, PartialEq)]
pub enum Json {
    Null,
    Bool(bool),
    Num(f64),
    Str(String),
    Arr(Vec<Json>),
    Obj(BTreeMap<String, Json>),
}

#[derive(Debug)]
pub struct JsonError {
    pub msg: String,
    pub pos: usize,
}

impl fmt::Display for JsonError {
    fn fmt(&self, f: &mut fmt::Formatter<'_>) -> fmt::Result {
        write!(f, "json error at byte {}: {}", self.pos, self.msg)
    }
}

impl std::error::Error for JsonError {}

impl Json {
    pub fn parse(text: &str) -> Result<Json, JsonError> {
        let mut p = Parser { b: text.as_bytes(), i: 0 };
        p.ws();
        let v = p.value()?;
        p.ws();
        if p.i != p.b.len() {
            return Err(p.err("trailing characters"));
        }
        Ok(v)
    }

    // ---- typed accessors -------------------------------------------------

    pub fn get(&self, key: &str) -> Option<&Json> {
        match self {
            Json::Obj(m) => m.get(key),
            _ => None,
        }
    }

    /// Object field access that errors with the path (for manifest loading).
    pub fn req(&self, key: &str) -> Result<&Json, JsonError> {
        self.get(key).ok_or(JsonError { msg: format!("missing field `{key}`"), pos: 0 })
    }

    pub fn as_f64(&self) -> Option<f64> {
        match self {
            Json::Num(n) => Some(*n),
            _ => None,
        }
    }

    pub fn as_usize(&self) -> Option<usize> {
        self.as_f64().map(|n| n as usize)
    }

    pub fn as_str(&self) -> Option<&str> {
        match self {
            Json::Str(s) => Some(s),
            _ => None,
        }
    }

    pub fn as_arr(&self) -> Option<&[Json]> {
        match self {
            Json::Arr(a) => Some(a),
            _ => None,
        }
    }

    pub fn as_obj(&self) -> Option<&BTreeMap<String, Json>> {
        match self {
            Json::Obj(m) => Some(m),
            _ => None,
        }
    }

    pub fn as_bool(&self) -> Option<bool> {
        match self {
            Json::Bool(b) => Some(*b),
            _ => None,
        }
    }

    // ---- construction helpers -------------------------------------------

    pub fn obj(pairs: Vec<(&str, Json)>) -> Json {
        Json::Obj(pairs.into_iter().map(|(k, v)| (k.to_string(), v)).collect())
    }

    pub fn num(n: f64) -> Json {
        Json::Num(n)
    }

    pub fn str(s: impl Into<String>) -> Json {
        Json::Str(s.into())
    }

    pub fn arr(v: Vec<Json>) -> Json {
        Json::Arr(v)
    }

    /// Compact serialization.
    pub fn dump(&self) -> String {
        let mut s = String::new();
        self.write(&mut s);
        s
    }

    fn write(&self, out: &mut String) {
        match self {
            Json::Null => out.push_str("null"),
            Json::Bool(b) => out.push_str(if *b { "true" } else { "false" }),
            Json::Num(n) => {
                if n.fract() == 0.0 && n.abs() < 1e15 {
                    out.push_str(&format!("{}", *n as i64));
                } else {
                    out.push_str(&format!("{n}"));
                }
            }
            Json::Str(s) => {
                out.push('"');
                for c in s.chars() {
                    match c {
                        '"' => out.push_str("\\\""),
                        '\\' => out.push_str("\\\\"),
                        '\n' => out.push_str("\\n"),
                        '\t' => out.push_str("\\t"),
                        '\r' => out.push_str("\\r"),
                        c if (c as u32) < 0x20 => out.push_str(&format!("\\u{:04x}", c as u32)),
                        c => out.push(c),
                    }
                }
                out.push('"');
            }
            Json::Arr(a) => {
                out.push('[');
                for (i, v) in a.iter().enumerate() {
                    if i > 0 {
                        out.push(',');
                    }
                    v.write(out);
                }
                out.push(']');
            }
            Json::Obj(m) => {
                out.push('{');
                for (i, (k, v)) in m.iter().enumerate() {
                    if i > 0 {
                        out.push(',');
                    }
                    Json::Str(k.clone()).write(out);
                    out.push(':');
                    v.write(out);
                }
                out.push('}');
            }
        }
    }
}

struct Parser<'a> {
    b: &'a [u8],
    i: usize,
}

impl<'a> Parser<'a> {
    fn err(&self, msg: &str) -> JsonError {
        JsonError { msg: msg.to_string(), pos: self.i }
    }

    fn ws(&mut self) {
        while self.i < self.b.len() && matches!(self.b[self.i], b' ' | b'\t' | b'\n' | b'\r') {
            self.i += 1;
        }
    }

    fn peek(&self) -> Option<u8> {
        self.b.get(self.i).copied()
    }

    fn eat(&mut self, c: u8) -> Result<(), JsonError> {
        if self.peek() == Some(c) {
            self.i += 1;
            Ok(())
        } else {
            Err(self.err(&format!("expected `{}`", c as char)))
        }
    }

    fn lit(&mut self, s: &str, v: Json) -> Result<Json, JsonError> {
        if self.b[self.i..].starts_with(s.as_bytes()) {
            self.i += s.len();
            Ok(v)
        } else {
            Err(self.err(&format!("expected `{s}`")))
        }
    }

    fn value(&mut self) -> Result<Json, JsonError> {
        match self.peek() {
            Some(b'n') => self.lit("null", Json::Null),
            Some(b't') => self.lit("true", Json::Bool(true)),
            Some(b'f') => self.lit("false", Json::Bool(false)),
            Some(b'"') => Ok(Json::Str(self.string()?)),
            Some(b'[') => self.array(),
            Some(b'{') => self.object(),
            Some(c) if c == b'-' || c.is_ascii_digit() => self.number(),
            _ => Err(self.err("unexpected character")),
        }
    }

    fn string(&mut self) -> Result<String, JsonError> {
        self.eat(b'"')?;
        let mut s = String::new();
        loop {
            let start = self.i;
            while self.i < self.b.len() && self.b[self.i] != b'"' && self.b[self.i] != b'\\' {
                self.i += 1;
            }
            s.push_str(std::str::from_utf8(&self.b[start..self.i]).map_err(|_| self.err("bad utf8"))?);
            match self.peek() {
                Some(b'"') => {
                    self.i += 1;
                    return Ok(s);
                }
                Some(b'\\') => {
                    self.i += 1;
                    let c = self.peek().ok_or(self.err("eof in escape"))?;
                    self.i += 1;
                    match c {
                        b'"' => s.push('"'),
                        b'\\' => s.push('\\'),
                        b'/' => s.push('/'),
                        b'n' => s.push('\n'),
                        b't' => s.push('\t'),
                        b'r' => s.push('\r'),
                        b'b' => s.push('\u{8}'),
                        b'f' => s.push('\u{c}'),
                        b'u' => {
                            if self.i + 4 > self.b.len() {
                                return Err(self.err("short \\u escape"));
                            }
                            let hex = std::str::from_utf8(&self.b[self.i..self.i + 4])
                                .map_err(|_| self.err("bad \\u"))?;
                            let code = u32::from_str_radix(hex, 16).map_err(|_| self.err("bad \\u"))?;
                            self.i += 4;
                            s.push(char::from_u32(code).unwrap_or('\u{fffd}'));
                        }
                        _ => return Err(self.err("bad escape")),
                    }
                }
                _ => return Err(self.err("unterminated string")),
            }
        }
    }

    fn number(&mut self) -> Result<Json, JsonError> {
        let start = self.i;
        if self.peek() == Some(b'-') {
            self.i += 1;
        }
        while self.i < self.b.len()
            && (self.b[self.i].is_ascii_digit() || matches!(self.b[self.i], b'.' | b'e' | b'E' | b'+' | b'-'))
        {
            self.i += 1;
        }
        std::str::from_utf8(&self.b[start..self.i])
            .ok()
            .and_then(|s| s.parse::<f64>().ok())
            .map(Json::Num)
            .ok_or(self.err("bad number"))
    }

    fn array(&mut self) -> Result<Json, JsonError> {
        self.eat(b'[')?;
        let mut out = Vec::new();
        self.ws();
        if self.peek() == Some(b']') {
            self.i += 1;
            return Ok(Json::Arr(out));
        }
        loop {
            self.ws();
            out.push(self.value()?);
            self.ws();
            match self.peek() {
                Some(b',') => self.i += 1,
                Some(b']') => {
                    self.i += 1;
                    return Ok(Json::Arr(out));
                }
                _ => return Err(self.err("expected `,` or `]`")),
            }
        }
    }

    fn object(&mut self) -> Result<Json, JsonError> {
        self.eat(b'{')?;
        let mut out = BTreeMap::new();
        self.ws();
        if self.peek() == Some(b'}') {
            self.i += 1;
            return Ok(Json::Obj(out));
        }
        loop {
            self.ws();
            let k = self.string()?;
            self.ws();
            self.eat(b':')?;
            self.ws();
            let v = self.value()?;
            out.insert(k, v);
            self.ws();
            match self.peek() {
                Some(b',') => self.i += 1,
                Some(b'}') => {
                    self.i += 1;
                    return Ok(Json::Obj(out));
                }
                _ => return Err(self.err("expected `,` or `}`")),
            }
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn parse_scalars() {
        assert_eq!(Json::parse("null").unwrap(), Json::Null);
        assert_eq!(Json::parse("true").unwrap(), Json::Bool(true));
        assert_eq!(Json::parse("-3.5e2").unwrap(), Json::Num(-350.0));
        assert_eq!(Json::parse("\"a\\nb\"").unwrap(), Json::Str("a\nb".into()));
    }

    #[test]
    fn parse_nested() {
        let j = Json::parse(r#"{"a": [1, 2, {"b": null}], "c": "x"}"#).unwrap();
        assert_eq!(j.get("a").unwrap().as_arr().unwrap().len(), 3);
        assert_eq!(j.get("c").unwrap().as_str(), Some("x"));
    }

    #[test]
    fn roundtrip() {
        let src = r#"{"arr":[1,2.5,"s",null,true],"n":-7,"o":{"k":"v"}}"#;
        let j = Json::parse(src).unwrap();
        let j2 = Json::parse(&j.dump()).unwrap();
        assert_eq!(j, j2);
    }

    #[test]
    fn rejects_garbage() {
        assert!(Json::parse("{").is_err());
        assert!(Json::parse("[1,]").is_err());
        assert!(Json::parse("\"unterminated").is_err());
        assert!(Json::parse("123abc").is_err());
    }

    #[test]
    fn unicode_escape() {
        assert_eq!(Json::parse(r#""A""#).unwrap(), Json::Str("A".into()));
    }

    #[test]
    fn manifest_shape_access() {
        let j = Json::parse(r#"{"model": {"d_model": 64}, "seq_buckets": [32, 64]}"#).unwrap();
        assert_eq!(j.req("model").unwrap().req("d_model").unwrap().as_usize(), Some(64));
        assert!(j.req("nope").is_err());
    }
}
