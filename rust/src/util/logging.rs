//! Tiny leveled logger. Level from ODC_LOG (error|warn|info|debug|trace);
//! default info. Timestamped relative to process start.

use std::sync::atomic::{AtomicU8, Ordering};
use std::sync::OnceLock;
use std::time::Instant;

#[derive(Clone, Copy, Debug, PartialEq, PartialOrd)]
pub enum Level {
    Error = 0,
    Warn = 1,
    Info = 2,
    Debug = 3,
    Trace = 4,
}

static LEVEL: AtomicU8 = AtomicU8::new(2);
static START: OnceLock<Instant> = OnceLock::new();

pub fn init() {
    START.get_or_init(Instant::now);
    if let Ok(v) = std::env::var("ODC_LOG") {
        let l = match v.to_ascii_lowercase().as_str() {
            "error" => Level::Error,
            "warn" => Level::Warn,
            "info" => Level::Info,
            "debug" => Level::Debug,
            "trace" => Level::Trace,
            _ => Level::Info,
        };
        LEVEL.store(l as u8, Ordering::Relaxed);
    }
}

pub fn enabled(level: Level) -> bool {
    (level as u8) <= LEVEL.load(Ordering::Relaxed)
}

pub fn log(level: Level, target: &str, msg: &str) {
    if !enabled(level) {
        return;
    }
    let t = START.get_or_init(Instant::now).elapsed();
    eprintln!("[{:>9.3}s {:<5} {}] {}", t.as_secs_f64(), format!("{level:?}").to_uppercase(), target, msg);
}

#[macro_export]
macro_rules! info {
    ($target:expr, $($arg:tt)*) => {
        $crate::util::logging::log($crate::util::logging::Level::Info, $target, &format!($($arg)*))
    };
}

#[macro_export]
macro_rules! debug {
    ($target:expr, $($arg:tt)*) => {
        $crate::util::logging::log($crate::util::logging::Level::Debug, $target, &format!($($arg)*))
    };
}

#[macro_export]
macro_rules! warn {
    ($target:expr, $($arg:tt)*) => {
        $crate::util::logging::log($crate::util::logging::Level::Warn, $target, &format!($($arg)*))
    };
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn default_level_is_info() {
        init();
        assert!(enabled(Level::Info));
    }
}
