//! Report emitters: render experiment results as the markdown tables /
//! CSV series mirroring the paper's tables and figures.

use std::fmt::Write as _;

/// A simple column-aligned markdown table builder.
pub struct Table {
    headers: Vec<String>,
    rows: Vec<Vec<String>>,
}

impl Table {
    pub fn new(headers: &[&str]) -> Self {
        Table { headers: headers.iter().map(|s| s.to_string()).collect(), rows: Vec::new() }
    }

    pub fn row(&mut self, cells: Vec<String>) -> &mut Self {
        assert_eq!(cells.len(), self.headers.len(), "row arity mismatch");
        self.rows.push(cells);
        self
    }

    pub fn markdown(&self) -> String {
        let ncol = self.headers.len();
        let mut widths: Vec<usize> = self.headers.iter().map(|h| h.len()).collect();
        for row in &self.rows {
            for (i, c) in row.iter().enumerate() {
                widths[i] = widths[i].max(c.len());
            }
        }
        let mut out = String::new();
        let emit = |out: &mut String, cells: &[String]| {
            out.push('|');
            for i in 0..ncol {
                let _ = write!(out, " {:<w$} |", cells[i], w = widths[i]);
            }
            out.push('\n');
        };
        emit(&mut out, &self.headers);
        out.push('|');
        for w in &widths {
            let _ = write!(out, "{}|", "-".repeat(w + 2));
        }
        out.push('\n');
        for row in &self.rows {
            emit(&mut out, row);
        }
        out
    }

    pub fn csv(&self) -> String {
        let mut out = self.headers.join(",");
        out.push('\n');
        for row in &self.rows {
            out.push_str(&row.join(","));
            out.push('\n');
        }
        out
    }
}

/// Format a throughput delta the way the paper's tables do: "(+36%)".
pub fn pct_delta(ours: f64, baseline: f64) -> String {
    let pct = (ours / baseline - 1.0) * 100.0;
    format!("({}{:.0}%)", if pct >= 0.0 { "+" } else { "" }, pct)
}

/// Format a 0..=1 fraction as a percentage cell ("87.3%") — used by
/// the sim CLI's bubble-rate and device-utilization lines.
pub fn pct(frac: f64) -> String {
    format!("{:.1}%", 100.0 * frac)
}

/// An ASCII sparkline-style histogram for Fig 7 style distribution plots.
pub fn ascii_hist(counts: &[usize], width: usize) -> String {
    let max = counts.iter().copied().max().unwrap_or(1).max(1);
    counts
        .iter()
        .map(|&c| {
            let n = (c * width).div_ceil(max);
            format!("{} {}", "#".repeat(n), c)
        })
        .collect::<Vec<_>>()
        .join("\n")
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn markdown_renders_aligned() {
        let mut t = Table::new(&["method", "val"]);
        t.row(vec!["ODC".into(), "1.0".into()]);
        t.row(vec!["Collective".into(), "0.8".into()]);
        let md = t.markdown();
        assert!(md.contains("| method     | val |"));
        assert!(md.lines().count() == 4);
    }

    #[test]
    fn csv_roundtrip() {
        let mut t = Table::new(&["a", "b"]);
        t.row(vec!["1".into(), "2".into()]);
        assert_eq!(t.csv(), "a,b\n1,2\n");
    }

    #[test]
    fn pct_delta_formats() {
        assert_eq!(pct_delta(1.36, 1.0), "(+36%)");
        assert_eq!(pct_delta(0.95, 1.0), "(-5%)");
    }

    #[test]
    fn pct_formats_fraction() {
        assert_eq!(pct(0.873), "87.3%");
        assert_eq!(pct(1.0), "100.0%");
        assert_eq!(pct(0.0), "0.0%");
    }

    #[test]
    #[should_panic(expected = "row arity")]
    fn arity_checked() {
        Table::new(&["a"]).row(vec!["1".into(), "2".into()]);
    }
}
