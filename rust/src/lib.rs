//! # ODC — On-Demand Communication for LLM post-training
//!
//! Reproduction of *"Revisiting Parameter Server in LLM Post-Training"*
//! (CS.DC 2026) as a three-layer Rust + JAX + Pallas system:
//!
//! * **L3 (this crate)** — the paper's coordination contribution: an FSDP
//!   training engine whose per-layer communication is pluggable between
//!   `Collective` (all-gather / reduce-scatter, per-layer barriers),
//!   `Odc` (point-to-point gather / scatter-accumulate, one barrier per
//!   minibatch) and `Hybrid` (§6.1 two-level sharding: params/grads
//!   within a node group, optimizer shards across groups —
//!   [`comm::HybridComm`]), the load-balancing algorithms (LocalSort,
//!   LB-Micro, LB-Mini, Verl variants) plus the pluggable dispatch
//!   layer ([`balance::dispatch`]: static plan replay or work-stealing
//!   queue pulls, bit-identical under any interleaving via the
//!   id-keyed gradient fold), and a discrete-event cluster simulator
//!   that regenerates every table and figure of the paper at testbed
//!   scale — including straggler/heterogeneous-fleet scenarios
//!   (`device_speed` in both the trainer and the sim) and ElasticWorld
//!   fault-tolerant elastic membership ([`comm::membership`]: device
//!   crash mid-minibatch, join at a minibatch boundary, deterministic
//!   shard takeover with replicated optimizer state — `fail_at` /
//!   `join_at` in both the trainer and the sim).
//! * **L2** — the JAX transformer (`python/compile/model.py`), AOT-lowered
//!   once to HLO text and executed from Rust via PJRT.
//! * **L1** — the Pallas flash-attention + shard-op kernels
//!   (`python/compile/kernels/`), verified against pure-jnp oracles.
//!
//! Python never runs on the training hot path.
//!
//! ## The zero-copy buffer subsystem
//!
//! The per-microbatch compute/comm path is steady-state allocation-free
//! and host-copy-free, built from four pieces that all lean on the
//! phase discipline documented in [`comm::shared`]:
//!
//! * [`comm::arena::PayloadArena`] — preallocated per-(server, client)
//!   push-payload buffers (the paper's Appendix B per-client RDMA
//!   buffers): `reduce_grad` under ODC never allocates and never
//!   contends with other clients.
//! * [`comm::gather_cache::GatherCache`] — minibatch-scoped parameter
//!   gathers (§6.2 caching): one-sided backends gather each layer once
//!   per minibatch; every further use is an `Arc` refcount clone.
//! * [`engine::bufplan::BufferPlan`] — the per-device bundle of all
//!   recurring trainer buffers (gather cache, gradient staging,
//!   recycled activation/token pools).
//! * [`runtime::Input::F32Shared`] / [`runtime::SharedSlice`] — shared
//!   PJRT inputs: the compute service uploads straight from the
//!   engine's `Arc` windows and releases them before replying, so
//!   callers recycle buffers in place.
//!
//! `cargo bench --bench comm_path` measures the win and records it in
//! `BENCH_hotpath.json` at the repo root.

pub mod balance;
pub mod comm;
pub mod config;
pub mod data;
pub mod engine;
pub mod report;
pub mod runtime;
pub mod sim;
pub mod util;

/// Crate version (mirrors Cargo.toml).
pub fn version() -> &'static str {
    env!("CARGO_PKG_VERSION")
}
