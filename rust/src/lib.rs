//! # ODC — On-Demand Communication for LLM post-training
//!
//! Reproduction of *"Revisiting Parameter Server in LLM Post-Training"*
//! (CS.DC 2026) as a three-layer Rust + JAX + Pallas system:
//!
//! * **L3 (this crate)** — the paper's coordination contribution: an FSDP
//!   training engine whose per-layer communication is pluggable between
//!   `Collective` (all-gather / reduce-scatter, per-layer barriers) and
//!   `Odc` (point-to-point gather / scatter-accumulate, one barrier per
//!   minibatch), the load-balancing algorithms (LocalSort, LB-Micro,
//!   LB-Mini, Verl variants), and a discrete-event cluster simulator that
//!   regenerates every table and figure of the paper at testbed scale.
//! * **L2** — the JAX transformer (`python/compile/model.py`), AOT-lowered
//!   once to HLO text and executed from Rust via PJRT.
//! * **L1** — the Pallas flash-attention + shard-op kernels
//!   (`python/compile/kernels/`), verified against pure-jnp oracles.
//!
//! Python never runs on the training hot path.

pub mod balance;
pub mod comm;
pub mod config;
pub mod data;
pub mod engine;
pub mod report;
pub mod runtime;
pub mod sim;
pub mod util;

/// Crate version (mirrors Cargo.toml).
pub fn version() -> &'static str {
    env!("CARGO_PKG_VERSION")
}
