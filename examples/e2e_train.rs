//! End-to-end driver: REAL FSDP training of a transformer through the
//! full three-layer stack (Rust coordinator -> PJRT -> HLO lowered from
//! JAX + Pallas), on a synthetic bigram corpus, with both communication
//! schemes. Proves all layers compose; results recorded in
//! EXPERIMENTS.md.
//!
//! Run (after `make artifacts`):
//!   cargo run --release --example e2e_train -- --preset small --steps 60
//!   cargo run --release --example e2e_train -- --preset m100 --steps 20   # ~100M params (slow on CPU)

use odc::config::{Balancer, CommScheme};
use odc::engine::trainer::{train, TrainerConfig};
use odc::util::cli::Cli;
use std::path::Path;

fn main() -> anyhow::Result<()> {
    let args = Cli::new("e2e_train", "end-to-end FSDP training through PJRT")
        .opt("preset", "small", "artifact preset (tiny|small|base|m100; see `make artifacts`)")
        .opt("world", "4", "simulated devices (threads)")
        .opt("minibs", "4", "samples per minibatch per device")
        .opt("steps", "60", "optimizer steps")
        .opt("scheme", "odc", "comm scheme: odc | collective | both")
        .opt("balancer", "lb-mini", "local-sort | lb-micro | lb-mini")
        .opt("lr", "0.003", "AdamW learning rate")
        .opt("seed", "0", "rng seed")
        .parse();

    let preset = args.get("preset").to_string();
    let dir = Path::new(env!("CARGO_MANIFEST_DIR")).join("artifacts").join(&preset);
    if !dir.join("manifest.json").exists() {
        anyhow::bail!("no artifacts at {dir:?} — run `make artifacts` (or `make artifacts-m100`)");
    }

    let balancer = match Balancer::parse(args.get("balancer")) {
        Some(b) => b,
        None => anyhow::bail!("unknown balancer {}", args.get("balancer")),
    };
    let schemes: Vec<CommScheme> = match args.get("scheme") {
        "odc" => vec![CommScheme::Odc],
        "collective" => vec![CommScheme::Collective],
        "both" => vec![CommScheme::Collective, CommScheme::Odc],
        other => anyhow::bail!("unknown scheme {other}"),
    };

    for scheme in schemes {
        let mut cfg = TrainerConfig::new(dir.clone());
        cfg.world = args.usize("world");
        cfg.minibs = args.usize("minibs");
        cfg.steps = args.usize("steps");
        cfg.seed = args.u64("seed");
        cfg.scheme = scheme;
        cfg.balancer = if scheme == CommScheme::Collective && balancer == Balancer::LbMini {
            Balancer::LbMicro // LB-Mini needs ODC
        } else {
            balancer
        };
        cfg.adam.lr = args.f64("lr") as f32;

        println!(
            "\n== {scheme} {} | preset {preset} | world {} | minibs {} | {} steps ==",
            cfg.balancer, cfg.world, cfg.minibs, cfg.steps
        );
        let t0 = std::time::Instant::now();
        let run = train(&cfg)?;
        let total = t0.elapsed().as_secs_f64();
        let total_tokens: u64 = run.logs.iter().map(|l| l.tokens).sum();
        println!("step     loss    tokens   wall(s)");
        let stride = (run.logs.len() / 12).max(1);
        for log in run.logs.iter().step_by(stride) {
            println!("{:>4}  {:>7.4}  {:>8}  {:>7.3}", log.step, log.loss, log.tokens, log.wall_s);
        }
        let last = run.logs.last().unwrap();
        println!(
            "final loss {:.4} | {} steps in {total:.1}s | {:.0} tokens/s overall",
            last.loss,
            run.logs.len(),
            total_tokens as f64 / total
        );
    }
    Ok(())
}
