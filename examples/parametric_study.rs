//! Figure 10 interactively: sweep any factor from the golden setting and
//! print the ODC/Collective acceleration curve.
//!
//! Run: cargo run --release --example parametric_study -- --factor devices

use odc::report::Table;
use odc::sim::parametric::{sweep, Factor};
use odc::util::cli::Cli;

fn main() {
    let args = Cli::new("parametric_study", "Fig 10 sweeps from the golden setting (Table 1)")
        .opt("factor", "all", "minibs | maxlen | packing | devices | all")
        .opt("steps", "12", "minibatches per point")
        .opt("seed", "11", "rng seed")
        .parse();

    let factors: Vec<Factor> = match args.get("factor") {
        "minibs" => vec![Factor::MinibatchSize],
        "maxlen" => vec![Factor::MaxLength],
        "packing" => vec![Factor::PackingRatio],
        "devices" => vec![Factor::Devices],
        _ => vec![Factor::MinibatchSize, Factor::MaxLength, Factor::PackingRatio, Factor::Devices],
    };

    for f in factors {
        let pts = sweep(f, &f.default_grid(), args.usize("steps"), args.u64("seed"));
        let mut t = Table::new(&[f.label(), "ODC/Collective"]);
        for p in &pts {
            let bar = "#".repeat(((p.ratio - 0.95).max(0.0) * 60.0) as usize);
            t.row(vec![format!("{}", p.x), format!("{:.3}x {bar}", p.ratio)]);
        }
        println!("{}", t.markdown());
    }
}
