//! Figure 14 / Appendix F: convergence verification. Trains the SAME
//! model+data under Collective and ODC and prints the two loss curves —
//! they must be (near-)identical, since ODC preserves synchronous
//! minibatch semantics exactly.
//!
//! Run (after `make artifacts`): cargo run --release --example convergence

use odc::config::{Balancer, CommScheme};
use odc::engine::trainer::{train, TrainerConfig};
use odc::util::cli::Cli;
use std::path::Path;

fn main() -> anyhow::Result<()> {
    let args = Cli::new("convergence", "Fig 14: ODC vs Collective loss-curve equivalence")
        .opt("preset", "tiny", "artifact preset")
        .opt("world", "2", "devices")
        .opt("steps", "12", "optimizer steps")
        .opt("minibs", "4", "samples per device per step")
        .parse();

    let dir = Path::new(env!("CARGO_MANIFEST_DIR")).join("artifacts").join(args.get("preset"));
    anyhow::ensure!(dir.join("manifest.json").exists(), "run `make artifacts` first");

    let mut runs = Vec::new();
    for scheme in [CommScheme::Collective, CommScheme::Odc] {
        let mut cfg = TrainerConfig::new(dir.clone());
        cfg.world = args.usize("world");
        cfg.minibs = args.usize("minibs");
        cfg.steps = args.usize("steps");
        cfg.scheme = scheme;
        cfg.balancer = Balancer::LbMicro; // identical plan under both schemes
        cfg.adam.lr = 3e-3;
        cfg.seed = 123;
        println!("training under {scheme} ...");
        runs.push(train(&cfg)?);
    }

    println!("\nstep  collective       odc          |delta|");
    let mut max_delta = 0.0f64;
    for (a, b) in runs[0].logs.iter().zip(&runs[1].logs) {
        let d = (a.loss - b.loss).abs();
        max_delta = max_delta.max(d);
        println!("{:>4}  {:>10.6}  {:>10.6}  {:.2e}", a.step, a.loss, b.loss, d);
    }
    println!("\nmax |loss delta| = {max_delta:.3e}  (float-noise level => semantics preserved)");
    anyhow::ensure!(max_delta < 1e-3, "curves diverged!");
    println!("convergence verification PASSED");
    Ok(())
}
