//! Quickstart: a 30-second tour of the library.
//!
//! 1. Draw a long-tailed LLM post-training workload (LongAlign fit).
//! 2. Balance it with LB-Micro and LB-Mini.
//! 3. Compare Collective vs ODC on the simulated A100 testbed.
//!
//! Run: `cargo run --release --example quickstart`

use odc::config::{Balancer, CommScheme, Dataset, PaperModel};
use odc::report::{pct_delta, Table};
use odc::sim::run::simulate_cell;

fn main() {
    println!("ODC quickstart — Revisiting Parameter Server in LLM Post-Training\n");
    let (model, ds, devices, steps, seed) = (PaperModel::M1_5B, Dataset::LongAlign, 8, 12, 7);

    let mut t = Table::new(&["method", "minibs=2", "minibs=4", "minibs=8"]);
    let cell = |scheme, bal, mb| simulate_cell(model, ds, scheme, bal, mb, devices, steps, seed);
    for (name, scheme, bal) in [
        ("Collective LB-Micro (FSDP baseline)", CommScheme::Collective, Balancer::LbMicro),
        ("ODC LB-Micro", CommScheme::Odc, Balancer::LbMicro),
        ("ODC LB-Mini", CommScheme::Odc, Balancer::LbMini),
    ] {
        let mut cells = vec![name.to_string()];
        for mb in [2usize, 4, 8] {
            let r = cell(scheme, bal, mb);
            let base = cell(CommScheme::Collective, Balancer::LbMicro, mb);
            let v = r.samples_per_sec_per_device;
            if name.starts_with("ODC") {
                cells.push(format!("{v:.3} {}", pct_delta(v, base.samples_per_sec_per_device)));
            } else {
                cells.push(format!("{v:.3} (bubble {:.0}%)", 100.0 * r.bubble_rate));
            }
        }
        t.row(cells);
    }
    println!("samples/s/device — {model} on {ds}, {devices} devices:\n\n{}", t.markdown());
    println!("Next steps:");
    println!("  cargo run --release --example e2e_train        # REAL training through PJRT");
    println!("  cargo run --release --example convergence      # Fig 14 loss-curve equivalence");
    println!("  cargo run --release --example parametric_study # Fig 10 sweeps");
    println!("  cargo bench                                    # every paper table/figure");
}
